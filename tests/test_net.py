"""repro.net: framing fuzz, RPC semantics, failure modes, loud degradation,
and event-loop server load behavior (many connections, partial writes,
slow-reader backpressure, mid-batch kills)."""
import concurrent.futures
import socket
import threading
import time

import numpy as np
import pytest

from repro.core.ps import FederatedPS
from repro.core.stats import StatsTable
from repro.net import (
    CallTimeout,
    ConnectionLost,
    FrameDecoder,
    FramingError,
    MethodTable,
    RemoteError,
    RPCClient,
    RPCServer,
    TruncatedStream,
    encode_frame,
)
from repro.net.framing import (
    METHOD_RESOLVE,
    REQUEST,
    HEADER,
    MAGIC,
    iter_frames,
    pack_payload,
)
from repro.net.shards import PSShardService


# ----------------------------------------------------------------- framing
def _random_frame(rng, max_arrays=3):
    env = {
        "s": "x" * int(rng.integers(0, 50)),
        "i": int(rng.integers(-(2**40), 2**40)),
        "nest": {"a": [1, 2, {"b": None}]},
    }
    arrays = []
    for _ in range(int(rng.integers(0, max_arrays + 1))):
        dt = rng.choice(["<f8", "<i8", "<f4", "|i1"])
        shape = tuple(int(d) for d in rng.integers(0, 5, size=int(rng.integers(1, 3))))
        arrays.append((rng.random(shape) * 100).astype(np.dtype(dt)))
    return (
        int(rng.integers(0, 2**16)),
        int(rng.integers(0, 3)),
        int(rng.integers(0, 2**32)),
        env,
        arrays,
    )


def _assert_frames_equal(got, want):
    assert len(got) == len(want)
    for g, (mid, kind, rid, env, arrays) in zip(got, want):
        assert (g.method_id, g.kind, g.request_id) == (mid, kind, rid)
        assert g.env == env
        assert len(g.arrays) == len(arrays)
        for a, b in zip(g.arrays, arrays):
            assert a.dtype == b.dtype and a.shape == b.shape
            assert np.array_equal(a, b)


def test_framing_roundtrip_fuzz_split_and_coalesced():
    """Any chunking of the byte stream — 1-byte dribble, random splits, or
    one giant coalesced read — yields the identical frame sequence."""
    rng = np.random.default_rng(0)
    frames = [_random_frame(rng) for _ in range(20)]
    blob = b"".join(encode_frame(*f[:4], f[4]) for f in frames)

    # coalesced: everything in one feed
    _assert_frames_equal(FrameDecoder().feed(blob), frames)

    for trial in range(5):
        cuts = np.sort(rng.integers(0, len(blob), size=int(rng.integers(1, 40))))
        chunks, prev = [], 0
        for c in list(cuts) + [len(blob)]:
            chunks.append(blob[prev:c])
            prev = int(c)
        _assert_frames_equal(list(iter_frames(chunks)), frames)

    # pathological: one byte at a time
    dec = FrameDecoder()
    got = []
    for i in range(len(blob)):
        got.extend(dec.feed(blob[i : i + 1]))
    dec.close()
    _assert_frames_equal(got, frames)


def test_framing_zero_length_payload():
    blob = encode_frame(7, REQUEST, 42, {})
    assert len(blob) == HEADER.size
    (frame,) = FrameDecoder().feed(blob)
    assert frame.env == {} and frame.arrays == ()
    assert frame.method_id == 7 and frame.request_id == 42


def test_framing_zero_length_array():
    (frame,) = FrameDecoder().feed(
        encode_frame(1, REQUEST, 1, {"k": 1}, [np.zeros((0, 7))])
    )
    assert frame.arrays[0].shape == (0, 7)


def test_framing_max_size_payload_boundary():
    env = {"pad": "y" * 100}
    payload_len = len(pack_payload(env))
    # exactly at the cap: decodes; one byte over: rejected before buffering
    (frame,) = FrameDecoder(max_payload=payload_len).feed(
        encode_frame(1, REQUEST, 1, env)
    )
    assert frame.env == env
    with pytest.raises(FramingError):
        FrameDecoder(max_payload=payload_len - 1).feed(encode_frame(1, REQUEST, 1, env))


def test_framing_corrupt_array_spec_is_framing_error():
    """A syntactically-valid envelope with a garbage array spec must raise
    FramingError (anything else escapes the reader threads' stream-error
    handling and wedges the client silently)."""
    import json

    from repro.net.framing import ENVLEN

    for spec in (
        {"dtype": "bogus", "shape": [2]},
        {"dtype": "<f8", "shape": [-1]},
        {"dtype": "<f8"},
        "not-a-dict",
    ):
        envelope = json.dumps({"env": {}, "arrays": [spec]}).encode()
        payload = ENVLEN.pack(len(envelope)) + envelope + b"\0" * 64
        blob = HEADER.pack(MAGIC, 1, REQUEST, 1, len(payload)) + payload
        with pytest.raises(FramingError):
            FrameDecoder().feed(blob)
    # non-object envelope / env
    for env_json in (b"[1,2]", b'{"env": 3}'):
        payload = ENVLEN.pack(len(env_json)) + env_json
        blob = HEADER.pack(MAGIC, 1, REQUEST, 1, len(payload)) + payload
        with pytest.raises(FramingError):
            FrameDecoder().feed(blob)


def test_framing_bad_magic_raises():
    blob = encode_frame(1, REQUEST, 1, {"a": 1})
    with pytest.raises(FramingError):
        FrameDecoder().feed(b"XXXX" + blob[len(MAGIC):])


def test_framing_truncated_stream_raises_cleanly():
    rng = np.random.default_rng(3)
    frames = [_random_frame(rng) for _ in range(3)]
    blob = b"".join(encode_frame(*f[:4], f[4]) for f in frames)
    for cut in (len(blob) - 1, len(blob) - HEADER.size // 2, 3):
        dec = FrameDecoder()
        dec.feed(blob[:cut])
        with pytest.raises(TruncatedStream):
            dec.close()
    # a clean EOF on a frame boundary is not an error
    dec = FrameDecoder()
    dec.feed(blob)
    dec.close()


# ------------------------------------------------------------- rpc semantics
def _echo_table():
    table = MethodTable()
    table.register("echo", lambda env, arrays: (env, arrays))
    table.register("boom", lambda env, arrays: (_ for _ in ()).throw(ValueError("nope")))
    # heavy: a sleeping handler must occupy a worker thread, not the loop
    table.register(
        "slow", lambda env, arrays: (time.sleep(float(env["s"])), ({}, ()))[1],
        heavy=True,
    )
    return table


def test_rpc_call_roundtrip_and_pipelining():
    server = RPCServer(_echo_table()).start()
    try:
        client = RPCClient(server.endpoint, timeout=10)
        env, arrays = client.call("echo", {"k": [1, "two"]}, [np.arange(6.0).reshape(2, 3)])
        assert env == {"k": [1, "two"]}
        assert np.array_equal(arrays[0], np.arange(6.0).reshape(2, 3))
        # pipelined: all requests in flight before any result is awaited
        futs = [client.call_async("echo", {"i": i}) for i in range(20)]
        outs = [client.wait(f)[0]["i"] for f in futs]
        assert outs == list(range(20))
        client.close()
    finally:
        server.stop()


def test_rpc_remote_error_and_unknown_method():
    server = RPCServer(_echo_table()).start()
    try:
        client = RPCClient(server.endpoint, timeout=10)
        with pytest.raises(RemoteError) as ei:
            client.call("boom")
        assert ei.value.remote_type == "ValueError" and "nope" in str(ei.value)
        # a failed call must not poison the connection
        assert client.call("echo", {"ok": 1})[0] == {"ok": 1}
        with pytest.raises(RemoteError):
            client.call("no.such.method")
        client.close()
    finally:
        server.stop()


def test_rpc_per_call_timeout():
    server = RPCServer(_echo_table()).start()
    try:
        client = RPCClient(server.endpoint, timeout=10)
        with pytest.raises(CallTimeout):
            client.call("slow", {"s": 2.0}, timeout=0.05)
        client.close()
    finally:
        server.stop()


def test_rpc_server_kill_then_reconnect():
    """Kill → typed ConnectionLost; restart on the same port → the same
    client transparently reconnects on its next call."""
    server = RPCServer(_echo_table()).start()
    port = server.endpoint[1]
    client = RPCClient(server.endpoint, timeout=5, connect_retries=3, retry_delay=0.05)
    assert client.call("echo", {"a": 1})[0] == {"a": 1}
    server.stop()
    with pytest.raises(ConnectionLost):
        client.call("echo", {"a": 2})
    server2 = RPCServer(_echo_table(), port=port).start()
    try:
        assert client.call("echo", {"a": 3})[0] == {"a": 3}
    finally:
        client.close()
        server2.stop()


def test_rpc_inflight_calls_fail_loudly_on_kill():
    server = RPCServer(_echo_table()).start()
    client = RPCClient(server.endpoint, timeout=5, connect_retries=1, retry_delay=0.01)
    fut = client.call_async("slow", {"s": 30.0})
    time.sleep(0.1)  # let the request reach the handler
    server.stop()
    with pytest.raises(ConnectionLost):
        client.wait(fut, timeout=5)
    client.close()


# -------------------------------------------------- federation degradation
def test_federated_ps_degrades_loudly_when_workers_die():
    """A socket federation whose shard workers die must surface a typed
    transport error from the data path — never silently drop updates."""
    tables = [MethodTable(), MethodTable()]
    for t in tables:
        PSShardService().register(t)
    servers = [RPCServer(t).start() for t in tables]
    fed = FederatedPS(
        8, transport="socket", endpoints=[s.endpoint for s in servers]
    )
    d = StatsTable(8).update_batch(np.arange(8), np.ones(8))
    fed.update_and_fetch(0, 0, d)
    assert fed.snapshot().table[0, 0] == 1.0
    for s in servers:
        s.stop()
    for shard in fed.shards:  # don't sit through the full reconnect backoff
        shard._client.connect_retries = 2
        shard._client.retry_delay = 0.02
    with pytest.raises(ConnectionLost):
        for step in range(3):  # first push may ride the half-dead socket
            fed.update_and_fetch(0, 1 + step, d)
    fed.close()


# ------------------------------------------------- event-loop server load
def test_evloop_many_concurrent_connections():
    """≥64 concurrent connections, each with pipelined in-flight requests,
    served correctly by the single loop thread."""
    server = RPCServer(_echo_table()).start()
    clients = []
    try:
        clients = [
            RPCClient(server.endpoint, timeout=30, connect_retries=3)
            for _ in range(64)
        ]
        futs = [
            (i, j, c.call_async("echo", {"i": i, "j": j}))
            for i, c in enumerate(clients)
            for j in range(10)
        ]
        for i, j, fut in futs:
            env, _ = clients[i].wait(fut)
            assert env == {"i": i, "j": j}
    finally:
        for c in clients:
            c.close()
        server.stop()


def _handshake(sock):
    """Resolve the method table on a raw socket; returns {name: id}."""
    sock.sendall(encode_frame(METHOD_RESOLVE, REQUEST, 0, {}))
    dec = FrameDecoder()
    while True:
        frames = dec.feed(sock.recv(1 << 20))
        if frames:
            return {str(k): int(v) for k, v in frames[0].env["methods"].items()}


def test_evloop_one_byte_partial_writes():
    """Requests dribbled one byte at a time (worst-case interleaved partial
    writes) must decode and answer exactly like coalesced ones."""
    server = RPCServer(_echo_table()).start()
    try:
        with socket.create_connection(server.endpoint, timeout=10) as sock:
            methods = _handshake(sock)
            blob = b"".join(
                encode_frame(methods["echo"], REQUEST, 100 + i, {"i": i})
                for i in range(3)
            )
            for k in range(len(blob)):
                sock.sendall(blob[k : k + 1])
            dec = FrameDecoder()
            got = []
            while len(got) < 3:
                got.extend(dec.feed(sock.recv(1 << 20)))
            assert [(f.request_id, f.env["i"]) for f in got] == [
                (100 + i, i) for i in range(3)
            ]
    finally:
        server.stop()


def test_evloop_slow_reader_backpressure():
    """A peer that requests big responses but stops reading must trip the
    outbound high-water mark (server pauses *reading* that connection — no
    unbounded buffering), must not block other connections, and must get
    every response once it resumes reading."""
    server = RPCServer(_echo_table(), high_water=64 << 10, low_water=8 << 10).start()
    n_req, payload = 64, np.zeros(32 << 10, np.uint8)
    try:
        with socket.create_connection(server.endpoint, timeout=30) as slow:
            methods = _handshake(slow)
            blob = b"".join(
                encode_frame(methods["echo"], REQUEST, 1 + i, {}, [payload])
                for i in range(n_req)
            )
            # The server will stop reading once ~64 KiB of responses are
            # queued, so our send must run on a side thread (it blocks when
            # the kernel buffers fill) while this thread checks liveness.
            sender = threading.Thread(target=slow.sendall, args=(blob,), daemon=True)
            sender.start()

            deadline = time.time() + 30
            while server.backpressure_pauses == 0:
                assert time.time() < deadline, "server never paused the slow reader"
                time.sleep(0.01)

            # The loop is not wedged: a second connection still gets served.
            other = RPCClient(server.endpoint, timeout=10)
            assert other.call("echo", {"ok": 1})[0] == {"ok": 1}
            other.close()

            # Resume reading: every response arrives, none dropped.
            dec = FrameDecoder()
            got = 0
            while got < n_req:
                data = slow.recv(1 << 20)
                assert data, "server closed the backpressured connection"
                for frame in dec.feed(data):
                    assert frame.arrays[0].nbytes == payload.nbytes
                    got += 1
            sender.join(timeout=10)
            assert not sender.is_alive()
        assert server.backpressure_pauses >= 1
    finally:
        server.stop()


def test_evloop_inbound_backpressure_behind_heavy_handler():
    """Requests pipelined behind an in-flight heavy handler are bounded:
    past pending_max the server stops *reading* the connection (frames stay
    in kernel buffers, not server memory) and resumes as the backlog
    drains — with every request still answered in order."""
    server = RPCServer(_echo_table(), pending_max=8).start()
    try:
        client = RPCClient(server.endpoint, timeout=30)
        slow_fut = client.call_async("slow", {"s": 0.5})
        futs = [client.call_async("echo", {"i": i}) for i in range(100)]
        client.wait(slow_fut)
        assert [client.wait(f)[0]["i"] for f in futs] == list(range(100))
        client.close()
    finally:
        server.stop()


# ------------------------------------------------------- client semantics
def test_request_id_wraparound_skips_inflight():
    """Request ids wrap at 2³² and must skip ids still awaiting responses."""
    server = RPCServer(_echo_table()).start()
    try:
        client = RPCClient(server.endpoint, timeout=10)
        client._next_rid = 0xFFFFFFFF - 1  # near the wrap boundary
        futs = [client.call_async("echo", {"i": i}) for i in range(5)]
        assert [client.wait(f)[0]["i"] for f in futs] == list(range(5))
        assert client._next_rid < 10  # wrapped past 2³²-1 back into [1, ...]
        # Collision: a still-pending rid must be skipped, not reused.
        blocker = concurrent.futures.Future()
        with client._pending_lock:
            client._pending[5] = (client._gen, "x", blocker)
        client._next_rid = 5
        env, _ = client.call("echo", {"ok": True})
        assert env == {"ok": True}
        assert 5 in client._pending  # the fake in-flight call kept its id
        with client._pending_lock:
            del client._pending[5]
        client.close()
    finally:
        server.stop()


def test_call_timeout_surfaces_method_name():
    """CallTimeout names the *method* even through name-less wait paths."""
    server = RPCServer(_echo_table()).start()
    try:
        client = RPCClient(server.endpoint, timeout=10)
        fut = client.call_async("slow", {"s": 30.0})
        with pytest.raises(CallTimeout, match="'slow'"):
            client.wait(fut, timeout=0.05)  # note: no name= passed
        client.close()
    finally:
        server.stop()


def test_buffered_sends_flush_on_wait_and_preserve_order():
    """A buffered (fire-and-forget) frame reaches the wire before any later
    unbuffered frame, and wait() flushes so a buffered future resolves."""
    calls = []
    table = MethodTable()
    table.register("a", lambda env, arrays: (calls.append(("a", env["i"])), ({}, ()))[1])
    table.register("b", lambda env, arrays: (calls.append(("b", env["i"])), ({}, ()))[1])
    server = RPCServer(table).start()
    try:
        client = RPCClient(server.endpoint, timeout=10)
        f1 = client.call_async("a", {"i": 0}, buffered=True)
        f2 = client.call_async("a", {"i": 1}, buffered=True)
        assert client._sendbuf  # still parked client-side
        client.call("b", {"i": 2})  # unbuffered: flushes the buffer first
        client.wait(f1)
        client.wait(f2)
        assert calls == [("a", 0), ("a", 1), ("b", 2)]
        # wait() alone must also flush: nothing else will.
        f3 = client.call_async("a", {"i": 3}, buffered=True)
        client.wait(f3)
        assert calls[-1] == ("a", 3)
        client.close()
    finally:
        server.stop()


def test_shard_service_unconfigured_is_typed_error():
    table = MethodTable()
    PSShardService().register(table)
    server = RPCServer(table).start()
    try:
        client = RPCClient(server.endpoint, timeout=5)
        with pytest.raises(RemoteError) as ei:
            client.call("ps.push", arrays=[np.zeros((1, 7))])
        assert "not configured" in str(ei.value)
        client.close()
    finally:
        server.stop()
