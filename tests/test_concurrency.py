"""Regression tests for the concurrency hazards this PR's analyzer found
(and we fixed) in the live code, plus end-to-end coverage of the runtime
thread-ownership sanitizer on a real server.

Each test names the lint rule that flags the original bug; the companion
fixtures under ``tests/data/lint_fixtures/`` (``gateway_inline_view_bad``,
``prov_light_configure_bad``) reproduce the pre-fix shapes and are asserted
in ``test_lint.py`` — together they demonstrate the analyzer would have
caught each bug before it shipped.
"""
import sys
import threading
import time

import pytest

from repro.core.ps import AnomalyFeed
from repro.lint import runtime as san
from repro.net.framing import RemoteError
from repro.net.server import MethodTable, RPCServer
from repro.net.shards import build_shard_table


# --------------------------------------------------- prov handlers are heavy
def test_prov_filesystem_handlers_registered_heavy():
    """lint: loop-blocking-io — prov.configure/flush/close hit the
    filesystem (makedirs/open/fsync/close) and must run on the worker
    pool, never inline on the RPC server's loop thread."""
    table = build_shard_table("prov")
    heavy = {name: hv for name, fn, hv in table._by_id.values()}
    assert heavy["prov.configure"] is True
    assert heavy["prov.flush"] is True
    assert heavy["prov.close"] is True
    # The ingest hot path stays light by design (buffered in-memory write).
    assert heavy["prov.add"] is False


# ------------------------------------------------ AnomalyFeed.subscribe race
def test_subscribe_during_dispatch_loses_no_subscriber():
    """lint: lockset-mixed — ``subscribe`` appended to ``_subscribers``
    bare while ``report_anomalies`` snapshots the list under ``_feed_lock``
    from another thread.  Hammer both sides; every subscriber registered
    before the final report must see the final report."""
    feed = AnomalyFeed()
    switch = sys.getswitchinterval()
    sys.setswitchinterval(1e-6)  # force contention at the bytecode level
    try:
        stop = threading.Event()

        def reporter():
            step = 0
            while not stop.is_set():
                feed.report_anomalies(rank=0, step=step, n_anomalies=1)
                step += 1

        rep = threading.Thread(target=reporter)
        rep.start()
        hits = []
        n_subs = 64
        for i in range(n_subs):
            feed.subscribe(lambda msg, i=i: hits.append(i))
        stop.set()
        rep.join()
    finally:
        sys.setswitchinterval(switch)
    assert len(feed._subscribers) == n_subs
    # One final report reaches every registered subscriber exactly once.
    hits.clear()
    feed.report_anomalies(rank=0, step=10**6, n_anomalies=0)
    assert sorted(hits) == list(range(n_subs))


# ----------------------------------------------- backpressure counter safety
def test_backpressure_counters_exact_under_contention():
    """lint: lockset-counter — ``backpressure_pauses``/``resumes`` were
    bare ``+=`` on the loop thread while tests/monitors read them
    cross-thread.  PR 8 moved them into lock-disciplined telemetry
    ``Counter``s; this hammers the server's own pause counter from many
    threads and demands an exact total (a bare += drops updates under
    contention), then checks the read side the old fields proxied to."""
    table = MethodTable()
    table.register("noop", lambda env, arrays: ({}, ()))
    server = RPCServer(table)
    per_thread, n_threads = 3000, 8
    base = server.backpressure_pauses  # ephemeral-port label could be reused
    switch = sys.getswitchinterval()
    sys.setswitchinterval(1e-6)
    try:
        def bump():
            for _ in range(per_thread):
                server._m_backpressure_pauses.inc()

        ts = [threading.Thread(target=bump) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    finally:
        sys.setswitchinterval(switch)
        server.stop()
    assert server.backpressure_pauses - base == per_thread * n_threads


# ------------------------------------------- sanitizer on a live RPC server
def _echo_table():
    table = MethodTable()
    table.register("echo", lambda env, arrays: (dict(env), arrays))
    table.register("boom", lambda env, arrays: (_ for _ in ()).throw(
        ValueError("boom")), heavy=True)
    return table


def test_sanitizer_silent_on_correctly_threaded_server():
    """With REPRO_SANITIZE=1 (the whole suite), a round-trip through light
    and heavy handlers crosses every guarded hot path — _service, _send,
    _flush_out, _drain_pending, _run_heavy, _complete_heavy — without a
    ThreadOwnershipError."""
    from repro.net.client import RPCClient

    assert san.ENABLED
    server = RPCServer(_echo_table()).start()
    client = RPCClient(server.endpoint, timeout=10)
    try:
        env, _ = client.call("echo", {"x": 1})
        assert env == {"x": 1}
        with pytest.raises(RemoteError):
            client.call("boom", {})
        env2, _ = client.call("echo", {"x": 2})  # server survived the heavy error
        assert env2 == {"x": 2}
    finally:
        client.close()
        server.stop()


def test_sanitizer_catches_cross_thread_send():
    """Calling a loop-owned method from a foreign thread raises before any
    state is touched — the dynamic complement of the static loop rules."""
    server = RPCServer(_echo_table()).start()
    try:
        class _FakeConn:
            closed = False

        deadline = time.monotonic() + 5
        while server._loop_thread is None and time.monotonic() < deadline:
            time.sleep(0.01)
        with pytest.raises(san.ThreadOwnershipError, match="_post"):
            server._send(_FakeConn(), b"nope")
    finally:
        server.stop()
