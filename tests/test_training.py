"""Training integration: convergence, exact restart, microbatching, DP compression."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import synthetic_batch
from repro.launch.steps import StepOptions, build_train_step, make_shard_ctx, make_train_state
from repro.launch.train import train
from repro.optim.adamw import OptConfig


def _fixed_batch_steps(arch="gemma-2b", steps=40, lr=3e-3):
    cfg = configs.smoke(arch)
    opts = StepOptions(
        ce_chunk=512,
        opt=OptConfig(peak_lr=lr, warmup_steps=5, decay_steps=200, weight_decay=0.0),
    )
    ctx = make_shard_ctx(cfg, None, 4, opts)
    step_fn = jax.jit(build_train_step(cfg, ctx, opts))
    state = make_train_state(cfg, 0)
    batch = synthetic_batch(cfg, 4, 32, seed=0)
    losses = []
    for _ in range(steps):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    return losses


def test_overfits_fixed_batch():
    """Optimization sanity: loss on a memorized batch must fall sharply."""
    losses = _fixed_batch_steps()
    assert losses[0] > 5.5  # ~ln(512)
    assert losses[-1] < losses[0] * 0.5, losses[::8]


def test_microbatch_equivalence():
    """Grad accumulation (microbatch=2) ≈ single-shot on the same batch."""
    cfg = configs.smoke("gemma-2b")
    batch = synthetic_batch(cfg, 4, 32, seed=1)
    outs = {}
    for mb in (1, 2):
        opts = StepOptions(ce_chunk=512, microbatch=mb,
                           opt=OptConfig(peak_lr=1e-3, warmup_steps=1, weight_decay=0.0))
        ctx = make_shard_ctx(cfg, None, 4, opts)
        step_fn = jax.jit(build_train_step(cfg, ctx, opts))
        state = make_train_state(cfg, 0)
        state, m = step_fn(state, batch)
        outs[mb] = state["params"]["embed"]
    # bf16 grad-sum ordering differs; Adam amplifies near-zero-grad elements
    # up to a full lr (1e-3) step, so tolerate |delta| ~ lr on a few entries.
    np.testing.assert_allclose(
        np.asarray(outs[1], np.float32), np.asarray(outs[2], np.float32),
        rtol=1e-2, atol=2e-3,
    )


def test_restart_exact_resume(tmp_path):
    """Crash at step 12, resume from ckpt → same final loss as uninterrupted."""
    kw = dict(arch="gemma-2b", steps=20, global_batch=4, seq=32,
              ckpt_interval=5, log_every=100)
    full = train(ckpt_dir=str(tmp_path / "a"), **kw)

    with pytest.raises(RuntimeError):
        train(ckpt_dir=str(tmp_path / "b"), fail_at=12, **kw)
    resumed = train(ckpt_dir=str(tmp_path / "b"), **kw)
    assert resumed["history"][0]["step"] == 10  # resumed from step-10 ckpt
    np.testing.assert_allclose(
        full["final_loss"], resumed["final_loss"], rtol=1e-5
    )


def test_straggler_mitigation_hook(tmp_path):
    out = train(
        arch="gemma-2b", steps=30, global_batch=4, seq=32,
        inject_straggler_at=25, log_every=100,
    )
    assert out["monitor"]["stragglers"] >= 1


_DP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.data.pipeline import synthetic_batch
from repro.launch.steps import make_dp_train_step, make_train_state
from repro.optim.adamw import OptConfig
cfg = configs.smoke("gemma-2b")
mesh = jax.make_mesh((4,), ("data",))
batch = synthetic_batch(cfg, 8, 32, seed=0)
results = {}
for compress in (False, True):
    step_fn, init_err = make_dp_train_step(
        cfg, mesh, OptConfig(peak_lr=3e-3, warmup_steps=5, weight_decay=0.0),
        compress=compress)
    state = make_train_state(cfg, 0)
    err = init_err(state["params"])
    losses = []
    for _ in range(30):
        state, err, m = step_fn(state, err, batch)
        losses.append(float(m["loss"]))
    results[compress] = losses
l0, l1 = results[False], results[True]
assert l0[-1] < l0[0] * 0.6, ("uncompressed did not converge", l0[::6])
assert l1[-1] < l1[0] * 0.6, ("compressed did not converge", l1[::6])
assert abs(l1[-1] - l0[-1]) / l0[-1] < 0.35, (l0[-1], l1[-1])
print("DP_COMPRESS_OK", l0[-1], l1[-1])
"""


def test_dp_compressed_training_converges():
    r = subprocess.run(
        [sys.executable, "-c", _DP_SCRIPT], capture_output=True, text=True,
        timeout=560, env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "DP_COMPRESS_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_serve_driver_runs():
    from repro.launch.serve import serve

    out = serve(arch="gemma-2b", n_requests=4, batch=2, prompt_len=8, max_new=4)
    assert out["requests"] == 4
    assert out["tokens"] == 16
    assert all(len(s) > 0 for s in out["samples"])
