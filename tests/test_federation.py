"""PS federation: shard routing, batching, aggregation, on-device mirror."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import stats as S
from repro.core.ps import BatchedPSClient, FederatedPS, ParameterServer
from repro.core.stats import StatsTable


def _random_deltas(rng, n_ranks, frames, F, grow_to=None):
    """Per-(rank, frame) delta tables from random event batches."""
    out = []
    for t in range(frames):
        for r in range(n_ranks):
            Ft = F if grow_to is None or t < frames // 2 else grow_to
            n = int(rng.integers(0, 80))
            fids = rng.integers(0, Ft, n)
            vals = rng.lognormal(3.0, 1.0, n)
            out.append((r, t, StatsTable(Ft).update_batch(fids, vals)))
    return out


@pytest.mark.parametrize("num_shards", [1, 2, 3, 4, 8])
def test_federated_bitmatches_single(num_shards):
    """Federated merge of random event streams == one global StatsTable."""
    rng = np.random.default_rng(num_shards)
    F = 37
    single = ParameterServer(F)
    fed = FederatedPS(F, num_shards=num_shards, aggregate_every=7)
    for r, t, d in _random_deltas(rng, n_ranks=6, frames=30, F=F):
        single.update_and_fetch(r, t, d)
        fed.update_and_fetch(r, t, d)
    assert np.array_equal(single.snapshot().table, fed.snapshot().table)
    assert fed.n_updates == single.n_updates


def test_federated_bitmatch_with_growth():
    """Cyclic slicing is stable when new fids grow the table mid-stream."""
    rng = np.random.default_rng(11)
    F, F2 = 20, 53
    single = ParameterServer(F)
    fed = FederatedPS(F, num_shards=4)
    for r, t, d in _random_deltas(rng, n_ranks=4, frames=24, F=F, grow_to=F2):
        single.update_and_fetch(r, t, d)
        fed.update_and_fetch(r, t, d)
    assert fed.num_funcs == F2
    assert np.array_equal(single.snapshot().table, fed.snapshot().table)


def test_batched_client_equivalence():
    """Batched vs unbatched clients converge to the same global stats."""
    rng = np.random.default_rng(3)
    F = 41
    plain = FederatedPS(F, num_shards=4)
    batched = FederatedPS(F, num_shards=4)
    clients = {r: BatchedPSClient(batched, r, batch_frames=5) for r in range(4)}
    for r, t, d in _random_deltas(rng, n_ranks=4, frames=23, F=F):
        plain.update_and_fetch(r, t, d)
        clients[r].update_and_fetch(r, t, d)
    for c in clients.values():
        c.flush()  # 23 % 5 != 0: there are pending deltas to drain
    a, b = plain.snapshot().table, batched.snapshot().table
    # Server-side merge order differs (coalesced vs per-frame), so exact
    # equality is up to float associativity of the Pébay merge.
    assert np.allclose(a, b, rtol=1e-9, atol=1e-12)
    assert batched.n_updates == sum(c.n_flushes for c in clients.values())


def test_batched_client_staleness_and_view():
    F = 8
    fed = FederatedPS(F, num_shards=2, aggregate_every=1)
    client = BatchedPSClient(fed, rank=0, batch_frames=3)
    d = StatsTable(F).update_batch(np.array([1, 1, 2]), np.array([10.0, 12.0, 5.0]))
    snap1 = client.update_and_fetch(0, 0, d)
    # nothing flushed yet: the server saw no pushes
    assert fed.n_updates == 0
    # the pending-inclusive view reflects the local delta immediately
    assert client.view()[1, S.N] == 2
    snap3 = None
    for step in (1, 2):
        snap3 = client.update_and_fetch(0, step, d)
    assert fed.n_updates == 1  # third frame triggered the flush
    assert snap3 is not None and snap3[1, S.N] == 6
    assert snap1 is not None  # pre-flush fetch returned the pending delta


def test_empty_merge_is_exact_copy():
    """merge_moments with an empty operand must not perturb the other side."""
    rng = np.random.default_rng(5)
    row = S.batch_moments(rng.lognormal(3, 1, 100))
    empty = S.empty_table(1)[0]
    assert np.array_equal(S.merge_moments(empty, row), row)
    assert np.array_equal(S.merge_moments(row, empty), row)


def test_partition_assemble_roundtrip():
    rng = np.random.default_rng(9)
    F = 29
    tab = StatsTable(F)
    tab.update_batch(rng.integers(0, F, 500), rng.lognormal(3, 1, 500))
    for nshards in (1, 2, 4, 7):
        parts = S.partition_table(tab.table, nshards)
        back = S.assemble_shards(parts, F)
        assert np.array_equal(back, tab.table)


def test_federated_concurrent_pushes():
    """Many threads hammering the federation still yield exact global stats."""
    import threading

    rng = np.random.default_rng(13)
    F, R, T = 31, 8, 25
    deltas = {
        r: [StatsTable(F).update_batch(rng.integers(0, F, 60), rng.lognormal(3, 1, 60))
            for _ in range(T)]
        for r in range(R)
    }
    fed = FederatedPS(F, num_shards=4, aggregate_every=3)
    single = ParameterServer(F)

    def worker(rank):
        for t, d in enumerate(deltas[rank]):
            fed.update_and_fetch(rank, t, d)

    threads = [threading.Thread(target=worker, args=(r,)) for r in range(R)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    for r in range(R):
        for t, d in enumerate(deltas[r]):
            single.update_and_fetch(r, t, d)
    a, b = fed.snapshot().table, single.snapshot().table
    # Thread interleaving reorders per-row merges; Pébay merges are exactly
    # order-independent in math but not in floats — counts/min/max stay
    # exact, moments agree to tolerance.
    assert np.array_equal(a[:, S.N], b[:, S.N])
    assert np.array_equal(a[:, S.MIN], b[:, S.MIN])
    assert np.array_equal(a[:, S.MAX], b[:, S.MAX])
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def _call_frame(rank, step, fids, runtimes):
    from repro.core import events as E

    rows, t = [], 0
    for f_, r_ in zip(fids, runtimes):
        rows.append((f_, E.ENTRY, t))
        rows.append((f_, E.EXIT, t + r_))
        t += r_ + 1
    fe = E.make_func_events(rows, rank=rank)
    fe = fe[np.argsort(fe["ts"], kind="stable")]
    return E.Frame(0, rank, step, fe, E.empty_comm_events(0))


def test_snapshot_never_smaller_than_pushed_delta():
    """Growth + stale snapshots must not shrink the client's global view.

    OnNodeAD copies the returned snapshot over its global stats and indexes
    it by fid — a snapshot with fewer rows than the frame it just pushed
    would crash labeling (regression: stale cached aggregate / stale
    batched-client snapshot returned at pre-growth size).
    """
    from repro.core.ad import OnNodeAD

    fed = FederatedPS(4, num_shards=2, aggregate_every=1000)  # agg stays stale
    ad = OnNodeAD(4, rank=0, ps_client=fed, min_samples=1)
    ad.process_frame(_call_frame(0, 0, [0, 1, 2], [10, 10, 10]))
    ad.process_frame(_call_frame(0, 1, [7, 7], [10, 12]))  # grows table to 8
    res = ad.process_frame(_call_frame(0, 2, [7, 3], [11, 10]))
    assert res.records is not None

    ps = ParameterServer(4)
    client = BatchedPSClient(ps, 0, batch_frames=3)
    ad2 = OnNodeAD(4, rank=0, ps_client=client, min_samples=1)
    for s in range(3):  # third frame flushes; _last_global has 4 rows
        ad2.process_frame(_call_frame(0, s, [0, 1], [10, 10]))
    ad2.process_frame(_call_frame(0, 3, [7], [10]))  # pending grows to 8
    res2 = ad2.process_frame(_call_frame(0, 4, [7, 5], [10, 10]))
    assert res2.records is not None


def test_anomaly_feed_on_federation():
    fed = FederatedPS(8, num_shards=2)
    seen = []
    fed.subscribe(seen.append)
    fed.report_anomalies(0, 0, 3)
    fed.report_anomalies(0, 1, 1)
    fed.report_anomalies(1, 0, 7)
    assert len(seen) == 3
    dash = fed.rank_dashboard()
    assert dash[0]["total"] == 4.0 and dash[1]["maximum"] == 7.0
    assert fed.frame_series(0) == [(0, 3), (1, 1)]


def test_monitor_federated_matches_plain():
    """End-to-end ChimbukoMonitor: federated PS == single PS on same stream."""
    from repro.core.sim import WorkloadGenerator, nwchem_like
    from repro.trace.monitor import ChimbukoMonitor

    spec = nwchem_like(anomaly_rate=0.004, roots_per_frame=4)
    g1 = WorkloadGenerator(spec, n_ranks=3, seed=7)
    g2 = WorkloadGenerator(spec, n_ranks=3, seed=7)
    m1 = ChimbukoMonitor(num_funcs=len(g1.registry), registry=g1.registry,
                         min_samples=30)
    m2 = ChimbukoMonitor(num_funcs=len(g2.registry), registry=g2.registry,
                         min_samples=30, ps_shards=4)
    for s in range(12):
        for r in range(3):
            m1.ingest(g1.frame(r, s)[0])
            m2.ingest(g2.frame(r, s)[0])
    assert np.array_equal(m1.ps.snapshot().table, m2.ps.snapshot().table)
    assert m2.summary()["ps_shards"] == 4
    m1.close()
    m2.close()


# ------------------------------------------------------ socket transport
@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_socket_transport_bitmatches_local(num_shards):
    """transport="socket" must be a pure shard relocation: every snapshot a
    client sees and the final global table bit-match local mode (stats rows
    travel as raw float64 bytes; the wire adds zero behavioral drift)."""
    from repro.launch.shard_server import LocalShardHost

    rng = np.random.default_rng(17 + num_shards)
    F, F2 = 37, 53
    local = FederatedPS(F, num_shards=num_shards, aggregate_every=7)
    with LocalShardHost(num_shards, kind="ps") as host:
        sock = FederatedPS(
            F, transport="socket", endpoints=host.endpoints, aggregate_every=7
        )
        assert sock.num_shards == num_shards
        for r, t, d in _random_deltas(rng, n_ranks=4, frames=20, F=F, grow_to=F2):
            a = local.update_and_fetch(r, t, d)
            b = sock.update_and_fetch(r, t, d)
            assert np.array_equal(a, b)  # same staleness, same bits, every push
        assert local.num_funcs == sock.num_funcs == F2  # growth crossed the wire
        assert np.array_equal(local.snapshot().table, sock.snapshot().table)
        assert sock.shard_load() == local.shard_load()
        sock.close()


def test_socket_transport_process_workers():
    """Same bit-match through real worker *processes* (the GIL-escaping
    topology benchmarked by bench_net_federation.py)."""
    from repro.launch.shard_server import ShardServerPool

    rng = np.random.default_rng(23)
    F = 29
    local = FederatedPS(F, num_shards=2, aggregate_every=5)
    with ShardServerPool(2, kind="ps") as pool:
        sock = FederatedPS(
            F, transport="socket", endpoints=pool.endpoints, aggregate_every=5
        )
        for r, t, d in _random_deltas(rng, n_ranks=3, frames=10, F=F):
            local.update_and_fetch(r, t, d)
            sock.update_and_fetch(r, t, d)
        assert np.array_equal(local.snapshot().table, sock.snapshot().table)
        sock.close()


def test_monitor_socket_transport_matches_local():
    """ChimbukoMonitor end-to-end on the socket transport == local PS."""
    from repro.core.sim import WorkloadGenerator, nwchem_like
    from repro.launch.shard_server import LocalShardHost
    from repro.trace.monitor import ChimbukoMonitor

    spec = nwchem_like(anomaly_rate=0.004, roots_per_frame=4)
    g1 = WorkloadGenerator(spec, n_ranks=2, seed=5)
    g2 = WorkloadGenerator(spec, n_ranks=2, seed=5)
    m1 = ChimbukoMonitor(num_funcs=len(g1.registry), registry=g1.registry,
                         min_samples=30, ps_shards=2)
    with LocalShardHost(2, kind="ps") as host:
        m2 = ChimbukoMonitor(num_funcs=len(g2.registry), registry=g2.registry,
                             min_samples=30, ps_transport="socket",
                             shard_endpoints=host.endpoints)
        for s in range(8):
            for r in range(2):
                m1.ingest(g1.frame(r, s)[0])
                m2.ingest(g2.frame(r, s)[0])
        assert np.array_equal(m1.ps.snapshot().table, m2.ps.snapshot().table)
        assert m2.summary()["ps_transport"] == "socket"
        m1.close()
        m2.close()


_FUNC_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import jax_ad as J
from repro.core.stats import StatsTable
mesh = jax.make_mesh((2, 4), ("ranks", "funcs"))
F = J.padded_num_funcs(30, 4)
step = J.make_distributed_ad_step(mesh, ("ranks",), min_count=10.0, func_axis="funcs")
rng = np.random.default_rng(0)
R, E = 2, 256
fids = rng.integers(0, 30, (R, E)).astype(np.int32)
durs = rng.lognormal(3, 0.4, (R, E)).astype(np.float32)
new_table, labels = step(J.init_table(F), jnp.asarray(fids), jnp.asarray(durs))
host = StatsTable(F)
host.update_batch(fids.reshape(-1).astype(np.int64), durs.reshape(-1).astype(np.float64))
np.testing.assert_allclose(np.asarray(new_table[:, 0]), host.counts(), rtol=1e-6)
np.testing.assert_allclose(np.asarray(new_table[:, 1]), host.means(), rtol=1e-4)
# label ownership: outlier on a row owned by the second funcs shard
fids2 = np.full((R, 4), 9, np.int32)
durs2 = np.full((R, 4), float(host.means()[9]), np.float32)
durs2[1, 2] = 1e6
_, labels2 = step(new_table, jnp.asarray(fids2), jnp.asarray(durs2))
lab = np.asarray(labels2)
assert lab[1, 2] == 1 and lab.sum() == 1, lab
# pallas-accelerated per-shard segment reduction
step_p = J.make_distributed_ad_step(
    mesh, ("ranks",), min_count=10.0, func_axis="funcs", use_pallas=True)
t2, _ = step_p(J.init_table(F), jnp.asarray(fids), jnp.asarray(durs))
np.testing.assert_allclose(np.asarray(t2[:, 0]), host.counts(), rtol=1e-6)
print("FUNC_SHARDED_AD_OK")
"""


def test_func_sharded_ad_multidevice():
    """funcs-axis shard_map federation == exact host stats + full labels."""
    r = subprocess.run(
        [sys.executable, "-c", _FUNC_SHARDED_SCRIPT],
        capture_output=True,
        text=True,
        timeout=300,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "FUNC_SHARDED_AD_OK" in r.stdout, r.stdout + r.stderr


def test_kernel_fid_offset():
    """Pallas moments kernel rebases fids into a contiguous shard block."""
    import jax.numpy as jnp

    from repro.kernels import ops as K

    rng = np.random.default_rng(2)
    fids = rng.integers(0, 32, 500).astype(np.int32)
    durs = rng.lognormal(3, 0.5, 500).astype(np.float32)
    host = StatsTable(32)
    host.update_batch(fids.astype(np.int64), durs.astype(np.float64))
    for base in (0, 8, 24):
        d = K.moments_table(jnp.asarray(fids), jnp.asarray(durs), 8, fid_offset=base)
        np.testing.assert_allclose(
            np.asarray(d[:, 0]), host.counts()[base : base + 8], rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(d[:, 1]), host.means()[base : base + 8], rtol=1e-4, atol=1e-3
        )


# ---------------------------------------------------- incremental refresh
@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_incremental_refresh_bitmatches_full_peek(num_shards):
    """The dirty-row delta-peek aggregate == the full-peek stitch, bitwise.

    `_refresh_aggregate` consumes each shard's dirty rows and scatters them
    over the cached aggregate; `snapshot()` re-stitches every shard's full
    table.  They must agree bit-for-bit at every refresh point, including
    across table growth."""
    rng = np.random.default_rng(num_shards + 100)
    F = 23
    fed = FederatedPS(F, num_shards=num_shards, aggregate_every=10**9)
    for r, t, d in _random_deltas(rng, n_ranks=4, frames=12, F=F, grow_to=37):
        fed.update_and_fetch(r, t, d)
        if rng.integers(0, 3) == 0:
            fed._refresh_aggregate()
            full = fed.snapshot().table
            incr = S.pad_table(fed._agg, full.shape[0])
            assert np.array_equal(incr, full)
    fed._refresh_aggregate()
    assert np.array_equal(S.pad_table(fed._agg, fed.num_funcs), fed.snapshot().table)


def test_peek_rows_is_delta_sized():
    """Refresh reads are O(changed): a delta touching one fid dirties at
    most one row on one shard, and a peek with no intervening push is
    empty."""
    from repro.core.ps import PSShard

    fed = FederatedPS(32, num_shards=4, aggregate_every=10**9)
    d = S.empty_table(32)
    d[7] = S.batch_moments(np.asarray([5.0, 6.0]))
    fed.update_and_fetch(0, 0, d)
    sizes = [len(sh.peek_rows()[0]) for sh in fed.shards]
    assert sum(sizes) == 1 and sizes[7 % 4] == 1
    assert all(len(sh.peek_rows()[0]) == 0 for sh in fed.shards)

    # and the peeked rows carry the merged values for exactly those fids
    shard = PSShard(0, 1, 8)
    d2 = S.empty_table(8)
    d2[3] = S.batch_moments(np.asarray([2.0]))
    shard.push(d2)
    idx, rows = shard.peek_rows()
    assert list(idx) == [3]
    assert np.array_equal(rows[0], d2[3])


def test_incremental_refresh_bitmatches_over_socket():
    """Same bit-match guarantee when shards answer ps.peek_rows over RPC."""
    from repro.launch.shard_server import LocalShardHost

    rng = np.random.default_rng(17)
    F = 19
    with LocalShardHost(2, kind="ps") as host:
        fed = FederatedPS(F, transport="socket", endpoints=host.endpoints,
                          aggregate_every=10**9)
        try:
            for r, t, d in _random_deltas(rng, n_ranks=3, frames=8, F=F):
                fed.update_and_fetch(r, t, d)
            fed.drain()
            fed._refresh_aggregate()
            full = fed.snapshot().table
            incr = S.pad_table(fed._agg, full.shape[0])
            assert np.array_equal(incr, full)
        finally:
            fed.close()


def test_failed_refresh_recovers_with_full_rebuild():
    """A refresh that dies after consuming some shards' dirty state must
    not leave the cached aggregate permanently missing those rows: the
    next refresh rebuilds from full peeks and restores the bit-match."""
    rng = np.random.default_rng(23)
    fed = FederatedPS(16, num_shards=2, aggregate_every=10**9)
    for r, t, d in _random_deltas(rng, n_ranks=2, frames=4, F=16):
        fed.update_and_fetch(r, t, d)
    # shard 0's dirty rows get consumed, then shard 1's peek blows up
    orig = fed.shards[1].peek_rows
    fed.shards[1].peek_rows = lambda: (_ for _ in ()).throw(OSError("down"))
    with pytest.raises(OSError):
        fed._refresh_aggregate()
    fed.shards[1].peek_rows = orig
    fed._refresh_aggregate()  # full-peek rebuild
    assert np.array_equal(S.pad_table(fed._agg, fed.num_funcs),
                          fed.snapshot().table)
    # and subsequent delta refreshes keep matching
    for r, t, d in _random_deltas(rng, n_ranks=2, frames=2, F=16):
        fed.update_and_fetch(r, t, d)
    fed._refresh_aggregate()
    assert np.array_equal(S.pad_table(fed._agg, fed.num_funcs),
                          fed.snapshot().table)
