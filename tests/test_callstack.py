"""Call-stack builder: vectorized path vs slow oracle, carryover, comm."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import events as E
from repro.core.callstack import CallStackBuilder
from repro.core.sim import WorkloadGenerator, nwchem_like, uniform_workload


def _simple_frame(rows, comm=(), rank=0, step=0):
    fe = E.make_func_events(rows, rank=rank)
    fe = fe[np.argsort(fe["ts"], kind="stable")]
    ce = E.empty_comm_events(len(comm))
    ce["rank"] = rank
    for i, (tag, partner, ts) in enumerate(comm):
        ce["tag"][i], ce["partner"][i], ce["ts"][i] = tag, partner, ts
    return E.Frame(0, rank, step, fe, ce)


def test_nested_calls():
    #  A [0, 100] contains B [10, 50] contains C [20, 30]; D [60, 90] in A
    frame = _simple_frame(
        [
            (0, E.ENTRY, 0),
            (1, E.ENTRY, 10),
            (2, E.ENTRY, 20),
            (2, E.EXIT, 30),
            (1, E.EXIT, 50),
            (3, E.ENTRY, 60),
            (3, E.EXIT, 90),
            (0, E.EXIT, 100),
        ]
    )
    b = CallStackBuilder()
    recs, ctx = b.process(frame)
    assert len(recs) == 4
    by_fid = {int(r["fid"]): r for r in recs}
    assert by_fid[0]["runtime"] == 100 and by_fid[0]["depth"] == 1
    assert by_fid[0]["n_children"] == 2
    assert by_fid[1]["n_children"] == 1 and by_fid[1]["parent_fid"] == 0
    assert by_fid[2]["depth"] == 3 and by_fid[2]["parent_fid"] == 1
    assert by_fid[3]["parent_fid"] == 0
    # ancestors of C (fid 2)
    c_idx = int(np.nonzero(recs["fid"] == 2)[0][0])
    chain = [f for (f, _, _) in ctx.ancestors(c_idx)]
    assert chain == [1, 0]


def test_carryover_across_frames():
    b = CallStackBuilder()
    f1 = _simple_frame([(0, E.ENTRY, 0), (1, E.ENTRY, 10)])
    recs, _ = b.process(f1)
    assert len(recs) == 0 and b.open_depth() == 2
    f2 = _simple_frame([(2, E.ENTRY, 20), (2, E.EXIT, 25), (1, E.EXIT, 40), (0, E.EXIT, 50)], step=1)
    recs, _ = b.process(f2)
    assert len(recs) == 3 and b.open_depth() == 0
    by_fid = {int(r["fid"]): r for r in recs}
    assert by_fid[0]["runtime"] == 50
    assert by_fid[1]["runtime"] == 30
    assert by_fid[1]["n_children"] == 1  # child completed in later frame
    assert by_fid[0]["n_children"] == 1


def test_comm_attribution():
    frame = _simple_frame(
        [(0, E.ENTRY, 0), (1, E.ENTRY, 10), (1, E.EXIT, 20), (0, E.EXIT, 30)],
        comm=[(0, 1, 15), (1, 1, 25)],
    )
    recs, ctx = b = CallStackBuilder().process(frame)
    by_fid = {int(r["fid"]): r for r in recs}
    assert by_fid[1]["n_msgs"] == 1  # ts=15 inside fid 1
    assert by_fid[0]["n_msgs"] == 1  # ts=25 inside fid 0 only
    assert (ctx.comm_entry_row >= 0).all()


def test_orphan_exit_slow_path():
    frame = _simple_frame([(5, E.EXIT, 1), (0, E.ENTRY, 2), (0, E.EXIT, 3)])
    b = CallStackBuilder()
    recs, _ = b.process(frame)
    assert len(recs) == 1
    assert b.n_orphan_exits == 1


@st.composite
def random_event_stream(draw):
    """Random well-formed nested call sequences, split into frames."""
    n_calls = draw(st.integers(1, 60))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    rows = []
    t = [0]

    def gen(depth):
        fid = int(rng.integers(0, 6))
        t[0] += int(rng.integers(1, 5))
        rows.append((fid, int(E.ENTRY), t[0]))
        for _ in range(int(rng.integers(0, 3)) if depth < 4 else 0):
            if len(rows) < 2 * n_calls:
                gen(depth + 1)
        t[0] += int(rng.integers(1, 5))
        rows.append((fid, int(E.EXIT), t[0]))

    while len(rows) < 2 * n_calls:
        gen(1)
    n_splits = draw(st.integers(0, 3))
    cuts = sorted(draw(st.lists(st.integers(0, len(rows)), min_size=n_splits, max_size=n_splits)))
    return rows, cuts


@given(random_event_stream())
@settings(max_examples=50, deadline=None)
def test_vectorized_matches_slow_oracle(stream):
    rows, cuts = stream
    pieces = np.split(np.arange(len(rows)), cuts)
    fast, slow = CallStackBuilder(), CallStackBuilder()
    all_fast, all_slow = [], []
    for step, piece in enumerate(pieces):
        chunk = [rows[i] for i in piece]
        frame = _simple_frame(chunk, step=step)
        recs, _ = fast.process(frame)
        all_fast.append(recs)
        # force the slow path by calling it directly
        ctx2 = _fresh_ctx(frame)
        recs2, _ = slow._process_tid_slow(
            0, frame.func_events, frame.comm_events, ctx2, np.arange(len(frame.comm_events))
        )
        all_slow.append(recs2)
    a = np.concatenate(all_fast)
    b = np.concatenate(all_slow)
    assert len(a) == len(b)
    for col in ("fid", "entry", "exit", "runtime", "depth", "n_children", "parent_fid"):
        np.testing.assert_array_equal(a[col], b[col], err_msg=col)


def _fresh_ctx(frame):
    from repro.core.callstack import FrameContext

    return FrameContext(
        tid_of_record=np.zeros(0, np.uint32),
        entry_fid={},
        entry_ts={},
        entry_depth={},
        entry_parent_row={},
        rec_entry_row=np.zeros(0, np.int64),
        comm_entry_row=np.full(len(frame.comm_events), -1, np.int64),
    )


def test_workload_generator_roundtrip():
    """Generated frames must reconstruct to exactly the generated truth."""
    gen = WorkloadGenerator(nwchem_like(anomaly_rate=0.05), n_ranks=3, seed=1)
    b = {r: CallStackBuilder(rank=r) for r in range(3)}
    for step in range(4):
        for rank in range(3):
            frame, truth = gen.frame(rank, step)
            recs, _ = b[rank].process(frame)
            assert len(recs) == len(truth)
            np.testing.assert_array_equal(recs["fid"], truth["fid"])
            np.testing.assert_array_equal(recs["entry"], truth["entry"])
            np.testing.assert_array_equal(recs["exit"], truth["exit"])
        assert b[rank].open_depth() == 0


def test_multi_tid():
    fe = np.concatenate(
        [
            E.make_func_events([(0, E.ENTRY, 0), (0, E.EXIT, 10)], tid=0),
            E.make_func_events([(1, E.ENTRY, 2), (1, E.EXIT, 5)], tid=1),
        ]
    )
    frame = E.Frame(0, 0, 0, fe, E.empty_comm_events(0))
    recs, ctx = CallStackBuilder().process(frame)
    assert len(recs) == 2
    assert set(recs["fid"]) == {0, 1}
