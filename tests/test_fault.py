"""repro.fault: crash tolerance for the shard federation.

Layers, mirroring the subsystem:

  * WAL — record/CRC discipline, torn-tail truncation, snapshot
    compaction, and the contract everything rests on: a killed shard
    replays its WAL to a *bit-exact* table (plus the seq dedup horizon).
  * policy — the capped-exponential backoff schedule is a pure function
    of the attempt index (deterministic: no jitter, no wallclock).
  * chaos — seeded determinism of the ChaosStream; a FlakyProxy
    injecting connection drops and torn frames at exact wire-frame
    ordinals, with the stub recovering to the exact no-fault table.
  * dial loop — RPCClient reconnect backoff (the reconnect-storm
    regression: delays double then cap; never hammer at a fixed period).
  * pool — supervised respawn on the same endpoint; spawn-failure and
    stop() leak hygiene (no orphan processes, no fds; ``-X dev`` clean).
  * end-to-end — SIGKILL live PS/prov workers at seed-chosen frames at
    S ∈ {1, 2, 4}; the run completes and the PS snapshot + provenance
    JSONL file family byte-match a no-fault run (exactly-once across
    the crash).
"""
import multiprocessing
import os
import socket
import struct
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core.ps import PSShard
from repro.core.sim import WorkloadGenerator, nwchem_like
from repro.core.stats import StatsTable
from repro.fault.chaos import ChaosStream, FlakyProxy, kill_process, tear_tail
from repro.fault.policy import DEFAULT_POLICY, RetryPolicy, backoff_delay
from repro.fault.wal import PSWal, read_wal_records, wal_path
from repro.launch.shard_server import LocalShardHost, ShardServerPool
from repro.net import ConnectionLost, RPCClient
from repro.net.shards import RemotePSShard
from repro.trace.monitor import ChimbukoMonitor

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _subproc_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _wait(pred, timeout=30.0, what="condition"):
    deadline = time.monotonic() + timeout
    while not pred():
        assert time.monotonic() < deadline, f"timeout waiting for {what}"
        time.sleep(0.02)


def _rand_push(rng, F):
    """One sparse delta in exactly the form the remote stub ships."""
    n = int(rng.integers(1, 50))
    delta = StatsTable(F).update_batch(
        rng.integers(0, F, n), rng.lognormal(3.0, 1.0, n)
    )
    idx = np.flatnonzero(delta[:, 0] > 0).astype(np.int64)
    return idx, np.ascontiguousarray(delta[idx])


# ================================================================== policy
def test_backoff_delay_capped_exponential():
    assert [backoff_delay(k, 0.05, 2.0) for k in range(8)] == [
        0.05, 0.1, 0.2, 0.4, 0.8, 1.6, 2.0, 2.0
    ]
    # pure function of the attempt index: no jitter between evaluations
    assert backoff_delay(3, 0.05, 2.0) == backoff_delay(3, 0.05, 2.0)


def test_retry_policy_delay_schedule():
    p = RetryPolicy(retries=6, base_delay=0.1, max_delay=1.0)
    assert list(p.delays()) == [0.1, 0.2, 0.4, 0.8, 1.0, 1.0]
    assert len(list(DEFAULT_POLICY.delays())) == DEFAULT_POLICY.retries


# =================================================================== chaos
def test_chaos_stream_deterministic():
    a, b = ChaosStream(1234), ChaosStream(1234)
    assert [a.next_u64() for _ in range(64)] == [b.next_u64() for _ in range(64)]
    assert [ChaosStream(1).below(10) for _ in range(4)] != [
        ChaosStream(2).below(10) for _ in range(4)
    ]
    c = ChaosStream(7)
    assert all(0 <= c.below(13) < 13 for _ in range(200))
    assert ChaosStream(9).pick(["x", "y", "z"]) == ChaosStream(9).pick(["x", "y", "z"])


def test_tear_tail(tmp_path):
    p = str(tmp_path / "f.bin")
    with open(p, "wb") as f:
        f.write(b"x" * 100)
    assert tear_tail(p, 30) == 70
    assert os.path.getsize(p) == 70
    assert tear_tail(p, 1000) == 0  # clamps at empty, never negative


# ===================================================================== WAL
def test_wal_replay_bitexact_with_growth_and_dedup(tmp_path):
    """The durability contract: restart + replay == the pre-crash table,
    bit for bit, including mid-stream growth; the seq horizon survives so
    replayed (duplicate) deliveries after restart are exact no-ops."""
    p = wal_path(str(tmp_path), 0)
    sh = PSShard(0, 1, 31, wal=PSWal(p, reset=True))
    rng = np.random.default_rng(5)
    for k in range(25):
        idx, rows = _rand_push(rng, 31)
        sh.push_rows(idx, rows, 31, seq=k)
    sh.grow(57)
    for k in range(25, 40):
        idx, rows = _rand_push(rng, 57)
        sh.push_rows(idx, rows, 57, seq=k)
    want = sh.stats.table.copy()
    n_pushes = sh.n_pushes
    sh.close()

    re = PSShard(0, 1, 31, wal=PSWal(p))
    assert re.stats.table.tobytes() == want.tobytes()
    assert re.stats.num_funcs == 57
    assert re.last_push_seq == 39
    assert re.n_pushes == n_pushes
    # duplicate delivery (a post-crash client replay) is skipped exactly
    idx, rows = _rand_push(rng, 57)
    re.push_rows(idx, rows, 57, seq=17)
    assert re.stats.table.tobytes() == want.tobytes()
    re.close()


def test_wal_torn_tail_truncated_then_replay_converges(tmp_path):
    """Crash mid-append leaves a torn final record: load() truncates back
    to the last intact one, and the client's replay of that (unacked)
    push re-applies it — converging on the exact full table."""
    p = wal_path(str(tmp_path), 0)
    sh = PSShard(0, 1, 23, wal=PSWal(p, reset=True))
    rng = np.random.default_rng(9)
    for k in range(10):
        idx, rows = _rand_push(rng, 23)
        sh.push_rows(idx, rows, 23, seq=k)
    before_last = sh.stats.table.copy()
    last_idx, last_rows = _rand_push(rng, 23)
    sh.push_rows(last_idx, last_rows, 23, seq=10)
    full = sh.stats.table.copy()
    sh.close()

    tear_tail(p, 5)  # rip bytes out of the final record
    re = PSShard(0, 1, 23, wal=PSWal(p))
    assert re.stats.table.tobytes() == before_last.tobytes()
    assert re.last_push_seq == 9
    # the stub's recovery replays the unacked push: exact convergence
    re.push_rows(last_idx, last_rows, 23, seq=10)
    assert re.stats.table.tobytes() == full.tobytes()
    re.close()


def test_wal_reader_stops_at_corruption(tmp_path):
    """A flipped byte mid-file fails that record's CRC; the reader keeps
    the intact prefix and reports the offset it ends at."""
    p = str(tmp_path / "c.wal")
    w = PSWal(p, reset=True)
    w.load()
    w.append_conf(0, 1, 8)
    offsets = [os.path.getsize(p)]
    for k in range(5):
        w.append_grow(8 + k)
        offsets.append(os.path.getsize(p))
    w.close()
    full, good = read_wal_records(p)
    assert len(full) == 6 and good == offsets[-1]

    with open(p, "rb+") as f:  # corrupt record 3's payload
        f.seek(offsets[2] + 10)
        b = f.read(1)
        f.seek(offsets[2] + 10)
        f.write(bytes([b[0] ^ 0xFF]))
    prefix, good2 = read_wal_records(p)
    assert len(prefix) == 3 and good2 == offsets[2]
    assert prefix == full[:3]


def test_wal_compaction_bounded_and_bitexact(tmp_path):
    """Compaction folds the log into CONF+SNAP without perturbing replay:
    the compacted file stays bounded and reopens to the identical state
    (table, n_pushes, seq horizon) as an unlogged twin shard."""
    p = wal_path(str(tmp_path), 0)
    sh = PSShard(0, 1, 19, wal=PSWal(p, compact_every=8, reset=True))
    twin = PSShard(0, 1, 19)
    rng = np.random.default_rng(3)
    sizes = []
    for k in range(64):
        idx, rows = _rand_push(rng, 19)
        sh.push_rows(idx, rows, 19, seq=k)
        twin.push_rows(idx, rows, 19, seq=k)
        sizes.append(os.path.getsize(p))
    assert sh.stats.table.tobytes() == twin.stats.table.tobytes()
    # the log was rewritten at least once: size is not monotone
    assert any(b < a for a, b in zip(sizes, sizes[1:]))
    n_pushes = sh.n_pushes
    sh.close()

    re = PSShard(0, 1, 19, wal=PSWal(p, compact_every=8))
    assert re.stats.table.tobytes() == twin.stats.table.tobytes()
    assert re.n_pushes == n_pushes
    assert re.last_push_seq == 63
    re.close()


# =============================================================== dial loop
def test_reconnect_backoff_schedule(monkeypatch):
    """Reconnect-storm regression: the dial loop sleeps the shared capped-
    exponential schedule — not a fixed period — and it is deterministic."""
    sleeps = []
    monkeypatch.setattr("repro.net.client.time.sleep", sleeps.append)
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()  # nothing listens here: every dial is refused
    with pytest.raises(ConnectionLost):
        RPCClient(("127.0.0.1", port), connect_retries=7,
                  retry_delay=0.25, retry_delay_max=2.0)
    assert sleeps == [0.25, 0.5, 1.0, 2.0, 2.0, 2.0]
    # a storm of N clients decays to one dial per client per cap period:
    # total sleep budget is sum of the capped schedule, not N * fixed-rate
    assert sum(sleeps) == pytest.approx(7.75)


def test_try_dial_single_attempt(monkeypatch):
    """try_dial (the degraded-mode probe) spends exactly one attempt and
    restores the blocking paths' full retry budget."""
    sleeps = []
    host = LocalShardHost(1, kind="ps")
    cli = RPCClient(host.endpoints[0], connect_retries=3, retry_delay=0.01)
    host.stop()
    monkeypatch.setattr("repro.net.client.time.sleep", sleeps.append)
    with pytest.raises(ConnectionLost):
        cli.call("ps.stats", {})  # detect the drop; blocking redial fails
    n0 = len(sleeps)
    assert cli.try_dial() is False
    assert len(sleeps) == n0  # the probe added no backoff sleeps
    assert cli.connect_retries == 3
    cli.close()


# ============================================================== flaky wire
def test_flaky_proxy_drop_and_torn_frame_recovery(tmp_path):
    """Connection drops and torn frames at exact seed-chosen wire-frame
    ordinals: the stub's window replays every unacked push after each
    recovery, and seq dedup keeps the re-sends exactly-once — the final
    table byte-matches an unfaulted local twin."""
    F = 29
    cs = ChaosStream(42)
    drop = 4 + cs.below(8)            # mid-stream connection kill
    trunc = 20 + cs.below(8)          # torn frame later on
    with LocalShardHost(1, kind="ps") as host:
        with FlakyProxy(host.endpoints[0], drop_at=(drop,),
                        truncate_at=(trunc,)) as proxy:
            stub = RemotePSShard(
                proxy.endpoint, 0, 1, F, wal_dir=str(tmp_path),
                policy=RetryPolicy(retries=8, base_delay=0.02),
            )
            twin = PSShard(0, 1, F)
            rng = np.random.default_rng(1)
            for k in range(40):
                idx, rows = _rand_push(rng, F)
                stub.push_sparse_nowait(idx, rows, F)
                twin.push_rows(idx, rows, F, seq=k)
            stub.drain()
            got = stub.peek_table()
            assert proxy.faults == 2
            assert got.tobytes() == twin.stats.table.tobytes()
            stub.close()


# ==================================================================== pool
def test_pool_supervisor_respawns_on_same_endpoint():
    with ShardServerPool(2, kind="both", supervise=True,
                         supervise_poll=0.05) as pool:
        eps = list(pool.endpoints)
        victim = pool.procs[1]
        kill_process(victim)
        _wait(lambda: pool.restarts >= 1, what="supervisor respawn")
        _wait(lambda: pool.procs[1].is_alive(), what="respawned worker")
        assert pool.endpoints == eps  # same address: stubs keep dialing it
        assert pool.procs[1].pid != victim.pid
        # ...and the respawn actually serves on that endpoint
        cli = RPCClient(tuple(eps[1]), connect_retries=40, retry_delay=0.05)
        env, _ = cli.call("metrics.snapshot")
        assert isinstance(env, dict)
        cli.close()


def test_pool_spawn_failure_leaks_nothing():
    """A worker that cannot bind kills the whole construction — and the
    already-spawned siblings with it; no process outlives the raise."""
    blocker = socket.socket()
    blocker.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    blocker.bind(("127.0.0.1", 0))
    blocker.listen(1)
    taken = blocker.getsockname()[1]
    try:
        with pytest.raises(RuntimeError, match="shard worker"):
            # worker 0 gets taken-1 (normally free), worker 1 collides
            ShardServerPool(2, kind="ps", port_base=taken - 1,
                            spawn_timeout=30.0)
    finally:
        blocker.close()
    _wait(lambda: not multiprocessing.active_children(),
          what="no orphan workers")


def test_pool_x_dev_teardown_clean():
    """Full lifecycle — spawn, SIGKILL, supervised respawn, stop — under
    ``-X dev -W error``: exit 0 with no ResourceWarning means no leaked
    process handles, pipe fds, or sockets."""
    script = textwrap.dedent("""
        import gc, os, signal, time
        from repro.launch.shard_server import ShardServerPool

        pool = ShardServerPool(2, kind="both", supervise=True,
                               supervise_poll=0.05)
        os.kill(pool.procs[0].pid, signal.SIGKILL)
        pool.procs[0].join(10)
        deadline = time.monotonic() + 30
        while pool.restarts < 1:
            assert time.monotonic() < deadline, "no respawn"
            time.sleep(0.02)
        pool.stop()
        assert pool.procs == []
        gc.collect()
        print("TEARDOWN-OK")
    """)
    out = subprocess.run(
        [sys.executable, "-X", "dev", "-W", "error", "-c", script],
        capture_output=True, text=True, timeout=120, env=_subproc_env(),
    )
    assert out.returncode == 0, out.stderr
    assert "TEARDOWN-OK" in out.stdout
    assert "ResourceWarning" not in out.stderr


# ============================================================== end-to-end
def _chaos_run(tmp, S, kills):
    """One full monitored run over socket transport; ``kills`` is a list
    of (frame_ordinal, worker_index) SIGKILLs injected mid-stream."""
    prov = os.path.join(tmp, "prov.jsonl")
    with ShardServerPool(S, kind="both", supervise=True,
                         supervise_poll=0.05) as pool:
        mon = ChimbukoMonitor(
            num_funcs=64, prov_path=prov, min_samples=8, alpha=6.0,
            provdb_shards=S,
            ps_transport="socket", provdb_transport="socket",
            shard_endpoints=pool.endpoints,
            ps_wal_dir=os.path.join(tmp, "wal"),
            fault_policy=RetryPolicy(retries=8, base_delay=0.05),
            run_info={"timestamp": 0.0},
        )
        spec = nwchem_like(anomaly_rate=0.02)
        for f in spec.funcs.values():
            f.anomaly_scale = 40.0
        gen = WorkloadGenerator(spec, n_ranks=3, seed=0)
        kill_at = dict(kills)
        nframe = 0
        for step in range(15):
            for rank in range(3):
                mon.ingest(gen.frame(rank, step)[0])
                nframe += 1
                if nframe in kill_at:
                    kill_process(pool.procs[kill_at[nframe]])
        snap = mon.ps.snapshot().table.copy()
        summ = mon.summary()
        mon.close()
        files = {}
        for name in sorted(os.listdir(tmp)):
            if name.startswith("prov.jsonl"):
                with open(os.path.join(tmp, name), "rb") as f:
                    files[name] = f.read()
        return snap, summ, files, pool.restarts


@pytest.mark.parametrize("S", [1, 2, 4])
def test_chaos_kill_bitexact_recovery(tmp_path, S):
    """Acceptance: SIGKILL a live PS/prov worker at seed-chosen frames
    mid-run; the supervisor respawns it, WAL/JSONL replay restores it,
    and the finished run byte-matches a no-fault run — PS snapshot and
    every provenance JSONL file — with the same anomaly count."""
    from repro.core.provenance import static_provenance

    static_provenance()  # settle lazy env mutations (jax backend probe) so
    # both runs' provenance headers capture the identical environment
    cs = ChaosStream(2024 + S)
    kills = [
        (10 + cs.below(10), cs.below(S)),   # a PS/prov worker, early
        (28 + cs.below(10), cs.below(S)),   # another (maybe same), later
    ]
    ref_dir, kill_dir = str(tmp_path / "ref"), str(tmp_path / "kill")
    os.makedirs(ref_dir)
    os.makedirs(kill_dir)
    ref_snap, ref_summ, ref_files, _ = _chaos_run(ref_dir, S, [])
    snap, summ, files, restarts = _chaos_run(kill_dir, S, kills)

    assert restarts >= 1, "supervisor never respawned a killed worker"
    assert snap.tobytes() == ref_snap.tobytes(), "PS snapshot diverged"
    assert set(files) == set(ref_files)
    for name in ref_files:
        assert files[name] == ref_files[name], f"{name} diverged"
    assert summ["anomalies"] == ref_summ["anomalies"] > 0
    assert "health" in summ and summ["health"]["ok"] in (True, False)
