"""Test-collection hygiene.

Several seed test modules import ``hypothesis`` at module scope.  The dev
dependency set (pyproject.toml ``[dev]``) declares it, but when running in
an environment without it we skip those modules instead of failing the whole
collection — the rest of the suite still runs.
"""
import importlib.util

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore = [
        "test_callstack.py",
        "test_misc.py",
        "test_stats.py",
        "test_federation_props.py",
    ]
