"""Test-collection hygiene + runtime hardening for the whole suite.

Several seed test modules import ``hypothesis`` at module scope.  The dev
dependency set (pyproject.toml ``[dev]``) declares it, but when running in
an environment without it we skip those modules instead of failing the whole
collection — the rest of the suite still runs.

Two suite-wide runtime switches live here as well:

* ``REPRO_SANITIZE=1`` turns on :mod:`repro.lint.runtime` before any
  ``repro`` module is imported, so every event-loop test doubles as a
  thread-ownership check (loop-owned code on the loop thread, heavy code
  off it).  Export it as ``0`` beforehand to opt out locally.
* A :mod:`faulthandler` deadlock watchdog: if any single test runs past
  ``REPRO_TEST_TIMEOUT`` seconds (default 180), every thread's stack is
  dumped to stderr and the process exits.  Concurrency bugs in the
  event-loop stack present as silent hangs; a traceback of the wedged
  threads beats a CI timeout with no evidence.  Set
  ``REPRO_TEST_TIMEOUT=0`` to disable (e.g. when stepping through a test
  in a debugger).
"""
import faulthandler
import importlib.util
import os
import sys

import pytest

# Must precede the first ``repro`` import anywhere in the test session:
# repro.lint.runtime reads the variable at import time.
os.environ.setdefault("REPRO_SANITIZE", "1")

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore = [
        "test_callstack.py",
        "test_misc.py",
        "test_stats.py",
        "test_federation_props.py",
    ]

_WATCHDOG_S = float(os.environ.get("REPRO_TEST_TIMEOUT", "180") or "0")


@pytest.fixture(autouse=True)
def _deadlock_watchdog():
    """Per-test deadline: dump all thread stacks and hard-exit on a hang."""
    if _WATCHDOG_S <= 0:
        yield
        return
    faulthandler.enable(file=sys.stderr)
    faulthandler.dump_traceback_later(_WATCHDOG_S, exit=True)
    try:
        yield
    finally:
        faulthandler.cancel_dump_traceback_later()
