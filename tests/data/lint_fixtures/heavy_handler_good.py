"""GOOD twin of heavy_handler_bad: the bulk handler is heavy=True, so it
runs on the worker pool; the light push handler touches no bulk reads."""


class ShardService:
    def build_table(self, table):
        table.register("shard.push", self._on_push)
        table.register("shard.all", self._serve_table, heavy=True)

    def _on_push(self, env, arrays):
        self._n += 1

    def _serve_table(self, env, arrays):
        return {"rows": self.store.dump_all()}, ()
