"""BAD twin: time.sleep reachable from the selector loop.

Each hazardous line carries an ``# EXPECT: <rule>`` marker; the test
parses those markers and asserts the analyzer reports exactly that
(rule, line) set — no more, no less.
"""
import time


class EventLoopServer:  # stand-in: matched by name, like the real base
    pass


class PacedServer(EventLoopServer):
    def _loop(self):
        while True:
            self._tick()

    def _tick(self):
        time.sleep(0.01)  # EXPECT: loop-blocking-sleep
