"""GOOD twin of loop_io_bad: the same filesystem work, offloaded.

Also a false-positive tripwire: ``.write()`` on a receiver that is not
file-shaped (an in-memory buffer) must stay silent on the loop.
"""
import os


class EventLoopServer:
    pass


class SpoolServer(EventLoopServer):
    def _loop(self):
        self._offload(self._rotate)
        self.buf.write(b"frame")  # in-memory accumulator: not a file handle

    def _rotate(self):
        # WORKER context: syscalls belong here.
        fh = open("b", "w")
        self._log_fh.write("rotated\n")
        os.replace("a", "b")
        self.path.write_text("done")
        return fh
