"""BAD twin: blocking socket calls on the event-loop thread."""
import socket


class EventLoopServer:
    pass


class PushServer(EventLoopServer):
    def _loop(self):
        self._pump()

    def _pump(self):
        peer = socket.create_connection(("viz", 80))  # EXPECT: loop-blocking-socket
        peer.sendall(b"frame")  # EXPECT: loop-blocking-socket
        data = self.sock.recv(4096)  # EXPECT: loop-blocking-socket
        return data
