"""BAD twin: lock discipline violated — the same attribute is guarded in
one method and touched bare in others."""
import threading


class Inbox:
    def __init__(self):
        self._lock = threading.Lock()
        self.items = []

    def put(self, x):
        with self._lock:
            self.items.append(x)

    def drain(self):
        return list(self.items)  # EXPECT: lockset-mixed

    def reset(self):
        self.items = []  # EXPECT: lockset-mixed
