"""BAD twin: nondeterminism inside a byte-deterministic module."""
# lint: deterministic — fixture: output must be byte-identical across runs
import random
import time


def emit(records, out):
    ranks = {r["rank"] for r in records}
    for rank in ranks:  # EXPECT: det-unordered-iter
        out.write(str(rank))
    header = {"generated": time.time()}  # EXPECT: det-wallclock
    header["salt"] = random.random()  # EXPECT: det-random
    return header
