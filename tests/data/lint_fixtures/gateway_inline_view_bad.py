"""Regression fixture: the pre-fix VizGateway shape, distilled.

Before this PR, the gateway's ``_handle_request`` computed view responses
*inline on the loop thread*; the view layer reaches a blocking federated
RPC client (``sendall`` / unguarded ``recv``).  One wedged shard then
stalled every viewer connection.  This fixture reproduces that call chain
so the test can assert the analyzer would have caught the original bug
(the shipped gateway now validates inline and offloads the view body).
"""


class EventLoopServer:
    pass


class ShardClient:
    def fetch(self, name):
        self.sock.sendall(name)  # EXPECT: loop-blocking-socket
        return self.sock.recv(1 << 16)  # EXPECT: loop-blocking-socket


class Gateway(EventLoopServer):
    def __init__(self):
        self.client = ShardClient()

    def _loop(self):
        self._handle_request(b"/dashboard")

    def _handle_request(self, path):
        return self.client.fetch(path)  # inline on the loop: the old bug
