"""GOOD twin of loop_sync_bad: non-blocking acquire on the loop; the
parking waits live on the worker pool."""


class EventLoopServer:
    pass


class WaityServer(EventLoopServer):
    def _loop(self):
        self._offload(self._gather)
        if self._lock.acquire(blocking=False):  # try-lock: never parks
            self._lock.release()

    def _gather(self):
        out = self.future.result()
        self.done_event.wait()
        return out
