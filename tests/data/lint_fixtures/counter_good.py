"""GOOD twin of counter_bad: the public counter takes the stats lock; a
loop-private tally (underscore name, never read cross-thread) stays bare."""
import threading


class EventLoopServer:
    pass


class MeteredServer(EventLoopServer):
    def __init__(self):
        self._stats_lock = threading.Lock()
        self.frames_served = 0
        self._spins = 0

    def _loop(self):
        self._account()

    def _account(self):
        with self._stats_lock:
            self.frames_served += 1
        self._spins += 1  # private: loop-thread-only bookkeeping
