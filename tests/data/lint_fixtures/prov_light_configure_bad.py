"""Regression fixture: the pre-fix provenance-shard registration, distilled.

Before this PR, ``prov.configure`` / ``prov.flush`` / ``prov.close`` were
registered *light*, so their ``makedirs``/``open``/``flush`` syscalls ran
inline on the RPC server's loop thread (the shipped shard table now
registers all three ``heavy=True``)."""
import os


class ProvShard:
    def build_table(self, table):
        table.register("prov.configure", self._configure)  # EXPECT: loop-heavy-handler

    def _configure(self, env, arrays):
        os.makedirs(env["dir"])  # EXPECT: loop-blocking-io
        self._fh = open(env["path"], "a")  # EXPECT: loop-blocking-io
        self._export_window()
        return {}, ()

    def _export_window(self):
        pass
