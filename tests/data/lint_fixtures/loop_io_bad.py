"""BAD twin: filesystem traffic on the event-loop thread."""
import os


class EventLoopServer:
    pass


class SpoolServer(EventLoopServer):
    def _loop(self):
        self._rotate("a", "b")

    def _rotate(self, old, new):
        fh = open(new, "w")  # EXPECT: loop-blocking-io
        self._log_fh.write("rotated\n")  # EXPECT: loop-blocking-io
        os.replace(old, new)  # EXPECT: loop-blocking-io
        self.path.write_text("done")  # EXPECT: loop-blocking-io
        return fh
