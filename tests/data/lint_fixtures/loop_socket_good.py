"""GOOD twin of loop_socket_bad: non-blocking recv behind the loop's own
BlockingIOError idiom; connect/sendall moved to the worker pool."""
import socket


class EventLoopServer:
    pass


class PushServer(EventLoopServer):
    def _loop(self):
        self._offload(self._dial)
        self._pump()

    def _pump(self):
        try:
            return self.sock.recv(4096)  # guarded: the loop's own idiom
        except BlockingIOError:
            return b""

    def _dial(self):
        peer = socket.create_connection(("viz", 80))
        peer.sendall(b"frame")
