"""BAD twin: synchronization primitives that park the loop thread."""


class EventLoopServer:
    pass


class WaityServer(EventLoopServer):
    def _loop(self):
        self._gather()

    def _gather(self):
        out = self.future.result()  # EXPECT: loop-blocking-sync
        self.done_event.wait()  # EXPECT: loop-blocking-sync
        self._lock.acquire()  # EXPECT: loop-blocking-sync
        return out
