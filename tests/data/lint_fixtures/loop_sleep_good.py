"""GOOD twin of loop_sleep_bad: the sleep runs on the worker pool."""
import time


class EventLoopServer:
    pass


class PacedServer(EventLoopServer):
    def _loop(self):
        while True:
            self._offload(self._tick)

    def _tick(self):
        # WORKER context (seeded through _offload): sleeping is fine here.
        time.sleep(0.01)
