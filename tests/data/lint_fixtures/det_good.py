"""GOOD twin of det_bad: sorted() pins the iteration order; the header is
stamped from frame metadata, not the wall clock.  Plain dict iteration is
insertion-ordered in modern Python and deliberately NOT flagged."""
# lint: deterministic — fixture: output must be byte-identical across runs


def emit(records, out, frame):
    ranks = {r["rank"] for r in records}
    for rank in sorted(ranks):
        out.write(str(rank))
    by_label = {r["label"]: r for r in records}
    for label in by_label:  # dict order is deterministic: no finding
        out.write(label)
    header = {"generated": frame.step}
    return header
