"""GOOD twin of lockset_bad: every access takes the lock (``__init__``
construction writes are exempt by definition — no second thread yet)."""
import threading


class Inbox:
    def __init__(self):
        self._lock = threading.Lock()
        self.items = []

    def put(self, x):
        with self._lock:
            self.items.append(x)

    def drain(self):
        with self._lock:
            return list(self.items)

    def reset(self):
        with self._lock:
            self.items = []
