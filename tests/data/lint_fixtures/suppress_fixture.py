"""Suppression mechanics: the same hazards as the bad twins, silenced by
``# lint: ignore`` comments at line and def granularity."""
import time


class EventLoopServer:
    pass


class QuietServer(EventLoopServer):
    def _loop(self):
        self._tick()
        self._nap()
        self._account()

    def _tick(self):
        time.sleep(0.01)  # lint: ignore[loop-blocking-sleep] — fixture: measured pause

    def _nap(self):  # lint: ignore — fixture: whole function waived
        time.sleep(0.01)
        self.future.result()

    def _account(self):
        self.frames += 1  # lint: ignore[lockset-counter] — fixture: single reader
