"""BAD twin: public observability counter bumped bare on the loop thread."""


class EventLoopServer:
    pass


class MeteredServer(EventLoopServer):
    def _loop(self):
        self._account()

    def _account(self):
        self.frames_served += 1  # EXPECT: lockset-counter
