"""BAD twin: a light (inline-on-loop) RPC handler that reaches bulk reads."""


class ShardService:
    def build_table(self, table):
        table.register("shard.push", self._on_push)
        table.register("shard.all", self._serve_table)  # EXPECT: loop-heavy-handler

    def _on_push(self, env, arrays):
        self._n += 1

    def _serve_table(self, env, arrays):
        # A full-table serialization: far too heavy for the loop thread.
        return {"rows": self.store.dump_all()}, ()
