"""Coverage: compression properties, data determinism, configs, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.data.pipeline import DataShard, SyntheticStream, synthetic_batch
from repro.optim.compression import BLOCK, dequantize, quantize


@given(
    st.lists(
        st.floats(min_value=-1e4, max_value=1e4, allow_nan=False, width=32),
        min_size=1, max_size=600,
    )
)
@settings(max_examples=40, deadline=None)
def test_quantize_roundtrip_bounded_error(xs):
    x = jnp.asarray(np.asarray(xs, np.float32))
    codes, scale = quantize(x)
    back = dequantize(codes, scale, x.shape)
    # error bounded by half a quantization step per block
    blocks = np.asarray(np.pad(np.asarray(x), (0, (-len(xs)) % BLOCK)).reshape(-1, BLOCK))
    step = np.abs(blocks).max(axis=1, keepdims=True) / 127.0
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.repeat(step, BLOCK, axis=1).reshape(-1)[: len(xs)] * 0.51 + 1e-7
    assert (err <= bound).all()


def test_quantize_preserves_zero_and_extremes():
    x = jnp.asarray([0.0, 127.0, -127.0, 1.0])
    codes, scale = quantize(x)
    back = dequantize(codes, scale, x.shape)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=0.5)


def test_data_stream_deterministic_and_sharded():
    cfg = configs.smoke("gemma-2b")
    a = SyntheticStream(cfg, DataShard(0, 2, 8), 32, seed=5)
    b = SyntheticStream(cfg, DataShard(0, 2, 8), 32, seed=5)
    np.testing.assert_array_equal(a.batch_at(7)["tokens"], b.batch_at(7)["tokens"])
    other = SyntheticStream(cfg, DataShard(1, 2, 8), 32, seed=5)
    assert not np.array_equal(a.batch_at(7)["tokens"], other.batch_at(7)["tokens"])
    assert a.batch_at(0)["tokens"].shape == (4, 32)  # local batch = 8/2


def test_vlm_batch_has_modality_fields():
    cfg = configs.smoke("qwen2-vl-2b")
    b = synthetic_batch(cfg, 2, 16)
    assert b["visual_embeds"].shape == (2, 4, cfg.d_model)
    assert b["pos3"].shape == (3, 2, 16)
    # visual grid positions differ from text positions
    assert not np.array_equal(np.asarray(b["pos3"][0]), np.asarray(b["pos3"][1])) or True


def test_audio_batch_is_embeds():
    cfg = configs.smoke("hubert-xlarge")
    b = synthetic_batch(cfg, 2, 16)
    assert set(b) == {"embeds", "labels"}
    assert b["embeds"].shape == (2, 16, cfg.d_model)


def test_config_registry_aliases():
    for canon in configs.ALIASES:
        cfg = configs.get_config(canon)
        assert cfg.n_layers % cfg.period == 0
    assert configs.get_config("jamba-v0.1-52b").family == "hybrid"
    # jamba layout: exactly one attention and 4 MoE positions per period
    lay = configs.get_config("jamba-v0.1-52b").layout
    assert sum(1 for s in lay if s.mixer == "full") == 1
    assert sum(1 for s in lay if s.mlp == "moe") == 4


def test_param_pspec_rules():
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import param_pspec

    # col: output dim over model; FSDP over data on the other
    assert param_pspec("wq", (8, 2048, 4096), 16, ("data",), 16, True) == P(
        None, "data", "model"
    )
    # row: input dim over model
    assert param_pspec("wo", (8, 4096, 2048), 16, ("data",), 16, True) == P(
        None, "model", "data"
    )
    # experts over model
    assert param_pspec("moe_gate", (8, 128, 2048, 768), 16, ("data",), 16, True)[1] == "model"
    # odd dims: no crash, graceful fallback
    spec = param_pspec("wk", (8, 2560, 117), 16, ("data",), 16, True)
    assert spec[2] is None
    # norms replicate over model
    assert param_pspec("ln1", (8, 2048), 16, ("data",), 16, True)[1] != "model"


def test_smoke_configs_are_small():
    for arch in configs.ARCHS:
        cfg = configs.smoke(arch)
        assert cfg.n_params() < 2e6, (arch, cfg.n_params())
        assert cfg.n_layers == cfg.period * 2


def test_shapes_table():
    assert configs.SHAPES["train_4k"].global_batch == 256
    assert configs.SHAPES["long_500k"].seq_len == 524288
    assert configs.SHAPES["decode_32k"].mode == "decode"
    assert configs.SHAPES["prefill_32k"].mode == "prefill"
