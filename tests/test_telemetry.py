"""repro.telemetry: registry semantics, exposition format, federation,
and self-tracing.

The registry's two load-bearing promises get the heaviest coverage:

* **Exactness under contention** — counters are lock-guarded, so N
  threads x M increments must total exactly N*M (a bare ``+=`` drops
  updates; that is the lockset-counter bug class repro.lint hunts).
* **Merge algebra** — histogram snapshots are integer vectors, so
  merging shard snapshots must be associative and commutative (the viz
  gateway federates ``metrics.snapshot`` replies in arrival order, which
  is nondeterministic).  Property-tested when hypothesis is available,
  with a fixed-seed fallback that always runs.

The federation test is end-to-end: two *out-of-process* shard workers +
the in-process gateway, scraped over a real socket through ``/metrics``.
"""
import json
import sys
import threading
import urllib.request

import pytest

from repro.telemetry import (
    CONTENT_TYPE,
    MetricRegistry,
    bucket_bounds,
    bucket_index,
    merge_snapshots,
    parse_exposition,
    render_exposition,
)
from repro.telemetry import registry as telemetry
from repro.telemetry.registry import BUCKET_COUNT, Histogram

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ======================================================================
# registry basics
# ======================================================================

def test_counter_gauge_histogram_basics():
    reg = MetricRegistry()
    c = reg.counter("c_total", "help")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = reg.gauge("g", "help")
    g.set(7)
    g.inc(2)
    g.dec()
    assert g.value == 8
    h = reg.histogram("h_us", "help")
    for v in (0, 1, 3, 100, 5000):
        h.observe(v)
    assert h.count == 5
    assert h.sum == 0 + 1 + 3 + 100 + 5000
    assert 0 < h.percentile(50) <= h.percentile(95)


def test_labels_children_and_reregistration():
    reg = MetricRegistry()
    fam = reg.counter("req_total", "help", ["method"])
    a = fam.labels(method="get")
    assert fam.labels(method="get") is a  # same label set -> same child
    assert fam.labels(method="put") is not a
    with pytest.raises(ValueError):
        fam.labels(verb="get")  # undeclared labelname
    assert reg.counter("req_total", "help", ["method"]) is fam
    with pytest.raises(ValueError):
        reg.gauge("req_total", "help", ["method"])  # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("req_total", "help", ["other"])  # labelnames mismatch


def test_disabled_mutators_are_noops():
    reg = MetricRegistry()
    c = reg.counter("c_total", "help")
    h = reg.histogram("h_us", "help")
    prev = telemetry.ENABLED
    try:
        telemetry.set_enabled(False)
        c.inc(100)
        h.observe(42)
    finally:
        telemetry.set_enabled(prev)
    assert c.value == 0
    assert h.count == 0


def test_bucket_index_boundaries():
    # le bounds are 1, 2, 4, ... 2**30, +Inf; index = first bound >= v.
    assert bucket_index(0) == 0
    assert bucket_index(1) == 0
    assert bucket_index(1.5) == 1
    assert bucket_index(2) == 1
    assert bucket_index(3) == 2
    assert bucket_index(2 ** 30) == 30
    assert bucket_index(2 ** 30 + 1) == BUCKET_COUNT - 1  # +Inf
    bounds = bucket_bounds()
    assert len(bounds) == BUCKET_COUNT
    assert bounds[-1] == float("inf")
    for v in (0, 1, 2, 3, 7, 1000, 2 ** 29 + 1):
        assert bounds[bucket_index(v)] >= v


def test_counter_exact_under_8_thread_contention():
    reg = MetricRegistry()
    c = reg.counter("contended_total", "help")
    per_thread, n_threads = 5000, 8
    switch = sys.getswitchinterval()
    sys.setswitchinterval(1e-6)
    try:
        ts = [
            threading.Thread(
                target=lambda: [c.inc() for _ in range(per_thread)]
            )
            for _ in range(n_threads)
        ]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    finally:
        sys.setswitchinterval(switch)
    assert c.value == per_thread * n_threads


# ======================================================================
# merge algebra
# ======================================================================

def _hist_snapshot(values):
    reg = MetricRegistry()
    fam = reg.histogram("m_us", "help", ["shard"])
    h = fam.labels(shard="s")
    for v in values:
        h.observe(v)
    return reg.snapshot()


def _merge2(a, b):
    return merge_snapshots([a, b])


def test_merge_associative_commutative_fixed_seed():
    import random

    rng = random.Random(7)
    snaps = [
        _hist_snapshot([rng.randrange(0, 1 << 20) for _ in range(50)])
        for _ in range(3)
    ]
    a, b, c = snaps
    left = _merge2(_merge2(a, b), c)
    right = _merge2(a, _merge2(b, c))
    assert json.dumps(left, sort_keys=True) == json.dumps(right, sort_keys=True)
    assert json.dumps(_merge2(a, b), sort_keys=True) == json.dumps(
        _merge2(b, a), sort_keys=True
    )
    # Merged totals are exact integer sums (snapshot layout: counts[32]
    # then sum then count).
    series = left["m_us"]["series"]
    (vec,) = series.values()
    assert vec[-1] == 150  # merged count
    assert vec[-2] == sum(
        s["m_us"]["series"][k][-2] for s in snaps for k in s["m_us"]["series"]
    )


def test_merge_proc_label_keeps_series_distinct():
    a = _hist_snapshot([10, 20])
    b = _hist_snapshot([30])
    merged = merge_snapshots([a, b], proc_label=["shard0", "shard1"])
    series = merged["m_us"]["series"]
    assert len(series) == 2  # per-proc series did not collapse
    procs = {dict(json.loads(k)).get("proc") for k in series}
    assert procs == {"shard0", "shard1"}
    assert "proc" in merged["m_us"]["labelnames"]


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(st.integers(min_value=0, max_value=1 << 31), max_size=40),
        st.lists(st.integers(min_value=0, max_value=1 << 31), max_size=40),
        st.lists(st.integers(min_value=0, max_value=1 << 31), max_size=40),
    )
    def test_merge_associative_commutative_property(xs, ys, zs):
        a, b, c = _hist_snapshot(xs), _hist_snapshot(ys), _hist_snapshot(zs)
        left = _merge2(_merge2(a, b), c)
        right = _merge2(a, _merge2(b, c))
        assert json.dumps(left, sort_keys=True) == json.dumps(
            right, sort_keys=True
        )
        assert json.dumps(_merge2(a, b), sort_keys=True) == json.dumps(
            _merge2(b, a), sort_keys=True
        )
        (vec,) = left["m_us"]["series"].values()
        assert vec[-1] == len(xs) + len(ys) + len(zs)


# ======================================================================
# exposition format
# ======================================================================

def _sample_registry():
    reg = MetricRegistry()
    reg.counter("req_total", "requests", ["method"]).labels(method="get").inc(3)
    reg.gauge("depth", "queue depth").set(2)
    h = reg.histogram("lat_us", "latency", ["server"]).labels(server="a:1")
    for v in (1, 5, 1000):
        h.observe(v)
    return reg


def test_render_parse_roundtrip_line_by_line():
    text = render_exposition(_sample_registry().snapshot())
    assert text.endswith("\n")
    # Every line must be a comment or a well-formed sample — checked here
    # explicitly even though parse_exposition enforces it, so a format
    # regression points at the exact line.
    for i, line in enumerate(text.splitlines(), 1):
        assert line.startswith("# ") or " " in line, f"line {i}: {line!r}"
    fams = parse_exposition(text)
    assert set(fams) == {"req_total", "depth", "lat_us"}
    assert fams["req_total"]["type"] == "counter"
    assert fams["lat_us"]["type"] == "histogram"
    samples = {n: (l, v) for n, l, v in fams["req_total"]["samples"]}
    assert samples["req_total"] == ({"method": "get"}, 3.0)
    # Histogram exposition: cumulative buckets, +Inf present, sum+count.
    names = [n for n, _l, _v in fams["lat_us"]["samples"]]
    assert "lat_us_sum" in names and "lat_us_count" in names
    inf_bucket = [
        v for n, l, v in fams["lat_us"]["samples"]
        if n == "lat_us_bucket" and l.get("le") == "+Inf"
    ]
    assert inf_bucket == [3.0]
    assert "version=0.0.4" in CONTENT_TYPE


def test_parse_rejects_malformed_expositions():
    with pytest.raises(ValueError):
        parse_exposition("not a metric line at all !!!\n")
    with pytest.raises(ValueError):  # sample without TYPE is fine, bad name is not
        parse_exposition("9bad_name 1\n")
    # Non-cumulative histogram buckets must be rejected.
    bad_hist = (
        "# TYPE h histogram\n"
        'h_bucket{le="1"} 5\n'
        'h_bucket{le="2"} 3\n'
        'h_bucket{le="+Inf"} 5\n'
        "h_sum 9\n"
        "h_count 5\n"
    )
    with pytest.raises(ValueError):
        parse_exposition(bad_hist)
    # Missing +Inf bucket must be rejected.
    with pytest.raises(ValueError):
        parse_exposition(
            "# TYPE h histogram\n" 'h_bucket{le="1"} 5\n' "h_sum 9\nh_count 5\n"
        )


# ======================================================================
# federation: /metrics over real sockets from out-of-process shards
# ======================================================================

def test_metrics_federated_from_out_of_process_shards():
    from repro.core.sim import WorkloadGenerator, nwchem_like
    from repro.launch.shard_server import ShardServerPool
    from repro.telemetry.federate import fetch_shard_snapshot
    from repro.trace.monitor import ChimbukoMonitor

    spec = nwchem_like(anomaly_rate=0.05)
    for f in spec.funcs.values():
        f.anomaly_scale = 40.0
    gen = WorkloadGenerator(spec, n_ranks=2, seed=3)
    with ShardServerPool(2, kind="both") as pool:
        monitor = ChimbukoMonitor(
            num_funcs=len(gen.registry), registry=gen.registry, min_samples=4,
            ps_transport="socket", provdb_transport="socket",
            shard_endpoints=pool.endpoints, viz_serve=0,
        )
        try:
            for step in range(4):
                for rank in range(2):
                    frame, _ = gen.frame(rank, step)
                    monitor.ingest(frame)
            # The reserved verb federates raw snapshots shard-by-shard...
            shard_snap = fetch_shard_snapshot(pool.endpoints[0])
            assert "repro_rpc_latency_us" in shard_snap
            assert "repro_loop_lag_us" in shard_snap
            # ...and /metrics serves the merged fleet view over HTTP.
            host, port = monitor.viz_gateway.endpoint
            resp = urllib.request.urlopen(
                f"http://{host}:{port}/metrics", timeout=30
            )
            assert resp.headers["Content-Type"].startswith("text/plain")
            fams = parse_exposition(resp.read().decode("utf-8"))
            for family in (
                "repro_loop_lag_us",
                "repro_rpc_latency_us",
                "repro_worker_queue_depth",
                "repro_backpressure_pauses_total",
                "repro_frame_stage_us",
                "repro_ps_update_us",
            ):
                assert family in fams, family
            procs = {
                labels["proc"]
                for fam in fams.values()
                for _n, labels, _v in fam["samples"]
                if "proc" in labels
            }
            assert {"gateway", "shard0", "shard1"} <= procs
        finally:
            monitor.close()


# ======================================================================
# self-trace: the tool's own spans in the Chrome-trace export
# ======================================================================

def test_self_trace_spans_validate(tmp_path):
    from repro.core.sim import WorkloadGenerator, nwchem_like
    from repro.export.chrome_trace import validate_trace
    from repro.telemetry.selftrace import SELF_TRACE_PID
    from repro.trace.monitor import ChimbukoMonitor

    spec = nwchem_like(anomaly_rate=0.05)
    for f in spec.funcs.values():
        f.anomaly_scale = 40.0
    gen = WorkloadGenerator(spec, n_ranks=2, seed=3)
    trace_path = str(tmp_path / "trace.json")
    monitor = ChimbukoMonitor(
        num_funcs=len(gen.registry), registry=gen.registry, min_samples=4,
        export_trace=trace_path, self_trace=True,
    )
    for step in range(4):
        for rank in range(2):
            frame, _ = gen.frame(rank, step)
            monitor.ingest(frame)
    monitor.close()
    counts = validate_trace(trace_path)
    assert counts["completes"] > 0
    with open(trace_path) as f:
        events = json.load(f)["traceEvents"]
    own = [e for e in events if e.get("pid") == SELF_TRACE_PID]
    spans = {e["name"] for e in own if e.get("ph") == "X"}
    assert any(n.startswith("ingest:") for n in spans)
    # The self process group is named so Perfetto shows it as its own track.
    procs = {
        e["args"]["name"]
        for e in events
        if e.get("ph") == "M" and e.get("name") == "process_name"
    }
    assert "repro.telemetry (self)" in procs


def test_self_trace_off_by_default(tmp_path):
    from repro.core.sim import WorkloadGenerator, nwchem_like
    from repro.telemetry.selftrace import SELF_TRACE_PID, get_self_tracer
    from repro.trace.monitor import ChimbukoMonitor

    # The tracer is a process-wide singleton; restore the fresh-process
    # default (off) in case an earlier test opted in.
    get_self_tracer().set_enabled(False)
    spec = nwchem_like(anomaly_rate=0.05)
    gen = WorkloadGenerator(spec, n_ranks=1, seed=3)
    trace_path = str(tmp_path / "trace.json")
    monitor = ChimbukoMonitor(
        num_funcs=len(gen.registry), registry=gen.registry, min_samples=4,
        export_trace=trace_path,
    )
    frame, _ = gen.frame(0, 0)
    monitor.ingest(frame)
    monitor.close()
    with open(trace_path) as f:
        events = json.load(f)["traceEvents"]
    assert not [e for e in events if e.get("pid") == SELF_TRACE_PID]
