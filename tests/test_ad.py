"""AD module + parameter server: detection, dist-vs-nondist, reduction, provenance."""
import threading

import numpy as np
import pytest

from repro.core.ad import OnNodeAD, SstdDetector, HbosDetector
from repro.core.ps import NonDistributedAD, ParameterServer
from repro.core.reduction import Reducer, select_kept_records
from repro.core.provenance import ProvenanceDB
from repro.core.sim import WorkloadGenerator, accuracy, nwchem_like, uniform_workload
from repro.core.stats import StatsTable


def test_sstd_flags_outliers():
    t = StatsTable(2)
    rng = np.random.default_rng(0)
    t.update_batch(np.zeros(300, np.int64), rng.normal(100, 5, 300))
    det = SstdDetector(alpha=6.0, min_samples=10)
    labels = det.label(t, np.zeros(3, np.int64), np.asarray([100.0, 250.0, 1.0]))
    assert labels.tolist() == [0, 1, 1]


def test_sstd_min_samples_guard():
    t = StatsTable(1)
    t.update_batch(np.zeros(3, np.int64), np.asarray([1.0, 2.0, 100.0]))
    det = SstdDetector(min_samples=10)
    assert det.label(t, np.zeros(1, np.int64), np.asarray([1e9])).tolist() == [0]


def test_onnode_ad_detects_injected(tmp_path):
    spec = nwchem_like(anomaly_rate=0.03)
    gen = WorkloadGenerator(spec, n_ranks=2, seed=3)
    ps = ParameterServer(len(gen.registry))
    ads = {
        r: OnNodeAD(len(gen.registry), rank=r, ps_client=ps, min_samples=30)
        for r in range(2)
    }
    preds, truths = [], []
    for step in range(30):
        for r in range(2):
            frame, truth = gen.frame(r, step)
            res = ads[r].process_frame(frame)
            ps.report_anomalies(r, step, res.n_anomalies)
            preds.append(res.records)
            truths.append(truth)
    acc = accuracy(np.concatenate(preds), np.concatenate(truths))
    # warmup frames have no labels yet, so recall is measured loosely
    assert acc["agreement"] > 0.95
    assert acc["precision"] > 0.6
    assert acc["n_pred_anomalies"] > 0
    # PS-side viz feeds exist
    dash = ps.rank_dashboard()
    assert set(dash.keys()) == {0, 1}
    assert len(ps.frame_series(0)) == 30


def test_distributed_matches_nondistributed():
    """Fig. 7 property: distributed AD ≈ exact single-instance AD."""
    n_ranks = 6
    spec = nwchem_like(anomaly_rate=0.02)
    gen_d = WorkloadGenerator(spec, n_ranks=n_ranks, seed=9)
    gen_s = WorkloadGenerator(spec, n_ranks=n_ranks, seed=9)
    ps = ParameterServer(len(gen_d.registry))
    dist = {
        r: OnNodeAD(len(gen_d.registry), rank=r, ps_client=ps, min_samples=30)
        for r in range(n_ranks)
    }
    single = NonDistributedAD(len(gen_s.registry), min_samples=30)
    agree, total = 0, 0
    for step in range(20):
        nd = single.process_frames([gen_s.frame(r, step)[0] for r in range(n_ranks)])
        for r in range(n_ranks):
            frame, _ = gen_d.frame(r, step)
            res = dist[r].process_frame(frame)
            a, b = res.records["label"], nd[r]["label"]
            assert len(a) == len(b)
            agree += int((a == b).sum())
            total += len(a)
    assert agree / total > 0.97  # paper reports 97.6%


def test_ps_concurrent_updates():
    ps = ParameterServer(4)
    t = StatsTable(4)
    rng = np.random.default_rng(1)
    fids = rng.integers(0, 4, 4000)
    vals = rng.lognormal(2, 0.5, 4000)
    t.update_batch(fids, vals)  # oracle over all data

    def worker(part):
        loc = StatsTable(4)
        delta = loc.update_batch(fids[part], vals[part])
        ps.update_and_fetch(0, 0, delta)

    threads = [
        threading.Thread(target=worker, args=(part,))
        for part in np.array_split(np.arange(4000), 8)
    ]
    [th.start() for th in threads]
    [th.join() for th in threads]
    assert np.allclose(ps.global_stats.table[:, :3], t.table[:, :3], rtol=1e-8)


def test_reduction_keeps_anomalies_and_neighbors():
    from repro.core.events import empty_exec_records

    recs = empty_exec_records(30)
    recs["fid"] = np.tile([7, 8], 15)
    recs["label"][:] = 0
    recs["label"][14] = 1  # fid 7 occurrence index 7
    kept = select_kept_records(recs, np.asarray([14]), k=2)
    # anomaly + 2 same-fid records each side: stream positions 10,12,14,16,18
    assert kept.tolist() == [10, 12, 14, 16, 18]


def test_reduction_factor_large():
    spec = nwchem_like(anomaly_rate=0.005)
    gen = WorkloadGenerator(spec, n_ranks=1, seed=5)
    ad = OnNodeAD(len(gen.registry), min_samples=50)
    red = Reducer(k=5)
    for step in range(40):
        frame, _ = gen.frame(0, step)
        red.reduce(ad.process_frame(frame))
    assert red.stats.factor > 5.0  # most calls are normal -> big reduction
    assert red.stats.n_kept >= red.stats.n_anomalies


def test_provenance_db(tmp_path):
    # rare but extreme anomalies: the regime the paper's 6-sigma rule targets
    spec = nwchem_like(anomaly_rate=0.005)
    for f in spec.funcs.values():
        f.anomaly_scale = 50.0
    gen = WorkloadGenerator(spec, n_ranks=1, seed=11)
    ad = OnNodeAD(len(gen.registry), min_samples=20)
    db = ProvenanceDB(
        path=str(tmp_path / "prov.jsonl"), registry=gen.registry, k_neighbors=3
    )
    total = 0
    for step in range(80):
        frame, _ = gen.frame(0, step)
        res = ad.process_frame(frame)
        total += db.ingest(res, frame.comm_events)
    assert total > 0 and len(db) == total
    doc = db.records[0]
    assert doc["anomaly"]["func"] in gen.registry._ids
    assert "call_stack" in doc and "neighbors" in doc
    # JSONL exists with run_info header
    lines = (tmp_path / "prov.jsonl").read_text().strip().splitlines()
    assert len(lines) == total + 1
    # query API
    anomaly_fid = doc["anomaly"]["fid"]
    hits = db.query(fid=anomaly_fid)
    assert doc in hits
    db.close()


def test_hbos_detector():
    det = HbosDetector(n_bins=16, threshold=4.0, min_samples=16)
    rng = np.random.default_rng(2)
    fids = np.zeros(500, np.int64)
    vals = rng.normal(50, 2, 500)
    det.update(fids, vals)
    t = StatsTable(1)  # unused by HBOS
    labels = det.label(t, np.asarray([0, 0]), np.asarray([50.0, 500.0]))
    assert labels.tolist() == [0, 1]
