"""Tracer, streams, monitor, checkpoint, viz — substrate behaviour tests."""
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.callstack import CallStackBuilder
from repro.core.events import FunctionRegistry
from repro.checkpoint import ckpt as CK
from repro.trace.monitor import ChimbukoMonitor
from repro.trace.stream import FrameStore, SSTChannel
from repro.trace.tracer import Tracer
from repro.viz.server import VizServer


def test_tracer_roundtrip():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner"):
            time.sleep(0.001)
        tr.comm(partner=3, nbytes=1024)
    frame = tr.drain(step=0)
    assert len(frame.func_events) == 4
    recs, ctx = CallStackBuilder().process(frame)
    assert len(recs) == 2
    by = {tr.registry.name_of(int(r["fid"])): r for r in recs}
    assert by["outer"]["n_children"] == 1
    assert by["inner"]["runtime"] >= 1000  # >= 1ms in us
    assert by["outer"]["n_msgs"] == 1


def test_tracer_filtering():
    tr = Tracer(filtered=True)
    with tr.span("keep"):
        for _ in range(10):
            with tr.span("noise", filterable=True):
                pass
    frame = tr.drain(0)
    assert len(frame.func_events) == 2  # only 'keep'
    assert tr.n_dropped == 20
    tr2 = Tracer(filtered=False)
    with tr2.span("keep"):
        for _ in range(10):
            with tr2.span("noise", filterable=True):
                pass
    assert len(tr2.drain(0).func_events) == 22


def test_sst_channel_threaded():
    ch = SSTChannel(capacity=4)
    tr = Tracer()

    def producer():
        for step in range(10):
            with tr.span("work"):
                pass
            ch.put(tr.drain(step))
        ch.close()

    t = threading.Thread(target=producer)
    t.start()
    frames = list(ch)
    t.join()
    assert len(frames) == 10
    assert [f.step for f in frames] == list(range(10))


def test_frame_store_roundtrip(tmp_path):
    store = FrameStore(str(tmp_path))
    tr = Tracer(rank=2)
    for step in range(3):
        with tr.span("a"):
            tr.comm(0, 64)
        store.write(tr.drain(step))
    assert store.ranks() == [2]
    assert store.steps(2) == [0, 1, 2]
    f = store.read(2, 1)
    assert f.rank == 2 and f.step == 1 and len(f.func_events) == 2
    assert len(list(store.replay(2))) == 3


def test_monitor_end_to_end(tmp_path):
    from repro.core.sim import WorkloadGenerator, nwchem_like

    spec = nwchem_like(anomaly_rate=0.004)
    for f in spec.funcs.values():
        f.anomaly_scale = 50.0
    gen = WorkloadGenerator(spec, n_ranks=4, seed=0)
    mon = ChimbukoMonitor(
        num_funcs=len(gen.registry), registry=gen.registry,
        prov_path=str(tmp_path / "prov.jsonl"), min_samples=20,
    )
    for step in range(60):
        for rank in range(4):
            frame, _ = gen.frame(rank, step)
            mon.ingest(frame)
    s = mon.summary()
    assert s["frames"] == 240
    assert s["anomalies"] > 0
    assert s["reduction_factor"] > 3
    assert s["provenance_records"] == s["anomalies"]
    viz = VizServer(mon)
    dash = viz.rank_dashboard(stat="total")
    assert len(dash["top"]) > 0
    series = viz.frame_series(0)
    assert len(series) == 60
    # function view on a step that kept records
    key = next(iter(mon.kept))
    fv = viz.function_view(key[0], key[1], x="entry", y="runtime")
    assert fv["points"] or not len(mon.kept[key])
    viz.dump(str(tmp_path / "viz.json"))
    with open(tmp_path / "viz.json") as fh:
        assert json.load(fh)["summary"]["frames"] == 240
    mon.close()


def test_monitor_straggler_detection():
    mon = ChimbukoMonitor(straggler_alpha=3.0, straggler_min_steps=5)
    fired = []
    mon.on_straggler(lambda ev: fired.append(ev))
    for step in range(20):
        times = {r: 0.10 + 0.001 * r for r in range(4)}
        if step == 15:
            times[2] = 0.50  # injected straggler
        mon.record_step_times(step, times)
    assert any(ev.rank == 2 and ev.step == 15 for ev in fired)
    assert len(mon.stragglers) >= 1


def test_checkpoint_atomic_and_resume(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    p = str(tmp_path / "ck")
    CK.save(p, 10, tree)
    CK.save(p, 20, jax.tree.map(lambda x: x * 2, tree))
    assert CK.latest_step(p) == 20
    step, restored = CK.load(p, target=tree)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(6).reshape(2, 3) * 2)
    assert restored["b"]["c"].dtype == jnp.bfloat16
    # older step still loadable
    step, r10 = CK.load(p, step=10, target=tree)
    np.testing.assert_array_equal(np.asarray(r10["a"]), np.arange(6).reshape(2, 3))
    # a stale tmp dir must not be visible as a checkpoint
    os.makedirs(os.path.join(p, "step_00000030.tmp"))
    assert CK.latest_step(p) == 20
    CK.prune(p, keep=1)
    assert CK.latest_step(p) == 20
    with pytest.raises(FileNotFoundError):
        CK.load(p, step=10)


def test_checkpoint_manager_async(tmp_path):
    mgr = CK.CheckpointManager(str(tmp_path / "ck"), interval=5, keep=2, async_save=True)
    tree = {"w": jnp.zeros((8, 8))}
    saved = 0
    for step in range(1, 21):
        tree = {"w": tree["w"] + 1}
        saved += int(mgr.maybe_save(step, tree))
    mgr.wait()
    assert saved == 4  # steps 5, 10, 15, 20
    out = mgr.restore_or_none(target=tree)
    assert out is not None
    step, restored = out
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.full((8, 8), 20.0))


def test_checkpoint_reshard(tmp_path):
    """Restore under a different sharding (elastic mesh change)."""
    import subprocess, sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import ckpt as CK
tree = {"w": jnp.arange(32.0).reshape(8, 4)}
CK.save("%s", 1, tree)
mesh = jax.make_mesh((4,), ("data",))
sh = {"w": NamedSharding(mesh, P("data", None))}
step, restored = CK.load("%s", target=tree, shardings=sh)
assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)
np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(32.0).reshape(8, 4))
print("RESHARD_OK")
""" % (str(tmp_path / "ck2"), str(tmp_path / "ck2"))
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=240,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "RESHARD_OK" in r.stdout, r.stdout + r.stderr
