"""Sequence-parallel attention & mamba == single-device reference (8 devices)."""
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.data.pipeline import synthetic_batch
from repro.models import model as M
from repro.models.common import init_params
from repro.models.model import ShardCtx

from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))

for arch in ("gemma2-2b", "falcon-mamba-7b", "jamba-v0.1-52b", "minicpm3-4b"):
    cfg = dataclasses.replace(
        configs.smoke(arch), compute_dtype=jnp.float32,
        moe_capacity_factor=16.0,
    )
    B, S = 4, 128  # S/4 = 32 per shard (>= 16·tp? _use_seq_parallel wants S >= 16*tp = 64)
    params = init_params(cfg, jax.random.key(0))
    batch = synthetic_batch(cfg, B, S, seed=1)
    ref = M.forward(cfg, params, batch)  # single-device semantics (no ctx)

    ctx = ShardCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
                   batch_shardable=True, seq_shard=True, remat="none")
    fwd = jax.jit(lambda p, b: M.forward(cfg, p, b, ctx))
    out = fwd(params, batch)
    d = float(jnp.abs(out - ref).max())
    scale = float(jnp.abs(ref).max())
    assert d < 1e-3 + 1e-4 * scale, (arch, d, scale)
    print(f"{arch}: seq-parallel matches, max diff {d:.2e} (scale {scale:.1f})")
print("SEQ_PARALLEL_OK")
"""


def test_seq_parallel_matches_reference():
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=580, env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "SEQ_PARALLEL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]
