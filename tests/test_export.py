"""repro.export: schema lock, stack well-formedness, topology equivalence."""
import gzip
import io
import json
import os

import numpy as np
import pytest

from repro.core.events import empty_exec_records
from repro.core.sim import WorkloadGenerator, nwchem_like
from repro.export.chrome_trace import ChromeTraceWriter, validate_trace
from repro.export.cli import main as export_main
from repro.export.provenance_export import (
    load_provenance_docs,
    render_provenance_trace,
)
from repro.export.record_stream import export_stream, iter_stream_frames
from repro.trace.monitor import ChimbukoMonitor
from repro.viz.server import VizServer

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "golden_trace.json")


# ----------------------------------------------------------------- helpers
def _recs(rows, rank=0, tid=0):
    """(fid, entry, exit, depth[, label]) rows -> EXEC_RECORD_DTYPE array."""
    out = empty_exec_records(len(rows))
    for i, row in enumerate(rows):
        fid, entry, exit_, depth = row[:4]
        out["fid"][i], out["entry"][i], out["exit"][i] = fid, entry, exit_
        out["runtime"][i] = exit_ - entry
        out["depth"][i] = depth
        out["label"][i] = row[4] if len(row) > 4 else 0
    out["rank"] = rank
    out["tid"] = tid
    return out


def golden_trace_bytes() -> bytes:
    """A tiny fixed trace exercising every event family (the schema lock)."""
    buf = io.StringIO()
    w = ChromeTraceWriter(out=buf)
    names = {1: "main", 2: "solve", 3: "io"}
    # frame 0 (completed calls only; their parent `main` is still open):
    # solve(10..40, anomalous), io(50..90){io(60..70)}
    w.add_frame(
        0, 0,
        _recs([(2, 10, 40, 2, 1), (3, 60, 70, 3), (3, 50, 90, 2)]),
        names, anomalies=[(0, 7, 4)], n_records=5, n_anomalies=1, ts=90,
    )
    # frame 1, same track: solve(120..140) plus `main`(0..150), the parent
    # carried open across the frame boundary — its descendants already
    # exported (entry 0 < the track's high-water mark), so it degrades to
    # an async fallback pair instead of retro-breaking thread nesting.
    w.add_frame(
        0, 1,
        _recs([(2, 120, 140, 2), (1, 0, 150, 1)]),
        names, n_records=2, n_anomalies=0, ts=150,
    )
    # another rank/tid: independent track
    w.add_frame(1, 0, _recs([(2, 30, 60, 1)], rank=1, tid=9), names,
                n_records=1, n_anomalies=0, ts=60)
    # a cross-rank message: SEND on rank 0 → RECV on rank 1 as a flow pair
    comm = {"partner": 1, "nbytes": 64, "tag": 5}
    w.flow_start(0, 0, "msg", 35, 1, args=comm)
    w.flow_finish(1, 9, "msg", 45, 1, args={**comm, "partner": 0})
    w.close()
    return buf.getvalue().encode("utf-8")


def _run_monitor(td, n_ranks=4, steps=10, seed=3, **monitor_kw):
    """Drive a deterministic workload through a monitor with export wired."""
    spec = nwchem_like(anomaly_rate=0.02)
    for f in spec.funcs.values():
        f.anomaly_scale = 40.0
    gen = WorkloadGenerator(spec, n_ranks=n_ranks, seed=seed)
    monitor = ChimbukoMonitor(
        num_funcs=len(gen.registry), registry=gen.registry, min_samples=20,
        prov_path=os.path.join(td, "provenance.jsonl"),
        stream_path=os.path.join(td, "stream.jsonl"),
        run_info={"timestamp": 0.0},
        **monitor_kw,
    )
    for step in range(steps):
        for rank in range(n_ranks):
            frame, _ = gen.frame(rank, step)
            monitor.ingest(frame)
    return monitor


def _offline_bytes(td) -> bytes:
    buf = io.StringIO()
    export_stream(os.path.join(td, "stream.jsonl"), out=buf)
    return buf.getvalue().encode("utf-8")


# ------------------------------------------------------------- golden file
def test_golden_trace_locked():
    """Byte-deterministic output, locked against the committed golden file.

    A diff here means the export schema changed: regenerate tests/data/
    golden_trace.json deliberately (see this test) and document the change
    in docs/export.md.
    """
    data = golden_trace_bytes()
    assert data == golden_trace_bytes()  # deterministic across invocations
    with open(GOLDEN, "rb") as f:
        assert data == f.read()


def test_golden_trace_contents():
    doc = json.loads(golden_trace_bytes())
    counts = validate_trace(doc)
    assert counts["durations"] == 5  # 4 on track (0,0) + 1 on (1,9)
    assert counts["async"] == 1  # the carried-open parent
    assert counts["instants"] == 1
    assert counts["counters"] == 3
    assert counts["flows"] == 1  # the cross-rank SEND→RECV arrow
    s = [e for e in doc["traceEvents"] if e["ph"] == "s"][0]
    f_ = [e for e in doc["traceEvents"] if e["ph"] == "f"][0]
    assert (s["cat"], s["id"]) == (f_["cat"], f_["id"]) == ("comm", 1)
    assert s["ts"] <= f_["ts"] and f_["bp"] == "e"
    inst = [e for e in doc["traceEvents"] if e["ph"] == "i"][0]
    assert inst["args"]["prov_seq"] == 7
    assert inst["args"]["severity"] == 4
    assert inst["args"]["func"] == "solve"
    assert inst["cname"] == "bad"
    # B/E reconstruct the call-stack nesting: outer io opens before inner io
    track0 = [e for e in doc["traceEvents"]
              if e.get("pid") == 0 and e["ph"] in "BE"]
    assert [(e["ph"], e["name"]) for e in track0[:4]] == [
        ("B", "solve"), ("E", "solve"), ("B", "io"), ("B", "io")]


def test_validator_rejects_malformed_flows():
    def _flow(ph, fid, ts, **kw):
        return {"ph": ph, "cat": "comm", "id": fid, "pid": 0, "tid": 0,
                "name": "msg", "ts": ts, "args": {}, **kw}

    with pytest.raises(ValueError, match="unpaired"):
        validate_trace({"traceEvents": [_flow("s", 1, 10)]})
    with pytest.raises(ValueError, match="unpaired"):
        validate_trace({"traceEvents": [_flow("f", 1, 10)]})
    with pytest.raises(ValueError, match="precedes"):
        validate_trace({"traceEvents": [_flow("s", 1, 10), _flow("f", 1, 5)]})
    with pytest.raises(ValueError, match="duplicate"):
        validate_trace({"traceEvents": [
            _flow("s", 1, 10), _flow("s", 1, 11), _flow("f", 1, 12)]})
    with pytest.raises(ValueError, match="missing cat"):
        validate_trace({"traceEvents": [
            {"ph": "s", "pid": 0, "tid": 0, "name": "msg", "ts": 1}]})
    # file order between the halves is free: f before s is fine
    counts = validate_trace({"traceEvents": [_flow("f", 1, 12), _flow("s", 1, 10)]})
    assert counts["flows"] == 1


def test_validator_rejects_malformed():
    base = {"traceEvents": [
        {"ph": "B", "pid": 0, "tid": 0, "name": "f", "ts": 1, "args": {}}]}
    with pytest.raises(ValueError, match="unbalanced"):
        validate_trace(base)
    bad_order = {"traceEvents": [
        {"ph": "B", "pid": 0, "tid": 0, "name": "f", "ts": 5, "args": {}},
        {"ph": "E", "pid": 0, "tid": 0, "name": "f", "ts": 9},
        {"ph": "B", "pid": 0, "tid": 0, "name": "g", "ts": 3, "args": {}},
        {"ph": "E", "pid": 0, "tid": 0, "name": "g", "ts": 4},
    ]}
    with pytest.raises(ValueError, match="regressed"):
        validate_trace(bad_order)
    with pytest.raises(ValueError, match="name"):
        validate_trace({"traceEvents": [
            {"ph": "B", "pid": 0, "tid": 0, "name": "f", "ts": 1, "args": {}},
            {"ph": "E", "pid": 0, "tid": 0, "name": "g", "ts": 2}]})


# ------------------------------------------------- stack well-formedness
@pytest.mark.parametrize("seed", [0, 1, 7])
def test_stack_wellformed_fuzz(seed, tmp_path):
    """Every B has a matching E, nesting valid, on real AD output streams."""
    monitor = _run_monitor(str(tmp_path), n_ranks=3, steps=10, seed=seed)
    n_kept = sum(len(v) for v in monitor.kept.values())
    monitor.close()
    counts = validate_trace(json.loads(_offline_bytes(str(tmp_path))))
    if n_kept:
        assert counts["durations"] + counts["async"] == n_kept
    # every anomaly the monitor kept shows up as an instant with a doc link
    assert counts["instants"] == sum(
        len(v) for v in monitor.anom_meta.values())


def test_carried_open_call_degrades_to_async():
    """A call completing frames after its descendants exported must not
    retro-break thread nesting: it rides the async rail instead."""
    buf = io.StringIO()
    w = ChromeTraceWriter(out=buf)
    w.add_frame(0, 0, _recs([(2, 10, 20, 2)]), {1: "root", 2: "leaf"})
    w.add_frame(0, 1, _recs([(1, 0, 50, 1)]), {1: "root", 2: "leaf"})
    w.close()
    doc = json.loads(buf.getvalue())
    counts = validate_trace(doc)
    assert counts["durations"] == 1 and counts["async"] == 1
    a = [e for e in doc["traceEvents"] if e["ph"] == "b"][0]
    assert a["name"] == "root" and a["cat"] == "carried" and a["ts"] == 0


# ------------------------------------------------- topology equivalence
def test_export_identical_across_shard_counts_and_transports(tmp_path):
    """Acceptance: byte-identical trace for the same logical run at
    S ∈ {1, 2, 4} local and S=2 over the socket transport."""
    variants = {}
    for S in (1, 2, 4):
        td = str(tmp_path / f"s{S}")
        os.makedirs(td)
        monitor = _run_monitor(td, provdb_shards=S)
        monitor.close()
        variants[f"local{S}"] = (td, _offline_bytes(td))
    td = str(tmp_path / "sock2")
    os.makedirs(td)
    from repro.launch.shard_server import LocalShardHost

    with LocalShardHost(2, kind="prov") as host:
        monitor = _run_monitor(td, provdb_transport="socket",
                               shard_endpoints=host.endpoints)
        monitor.provdb.drain()
        monitor.close()
    variants["socket2"] = (td, _offline_bytes(td))

    ref_td, ref = variants["local1"]
    for label, (td, data) in variants.items():
        assert data == ref, f"{label} trace differs from single-shard local"
        with open(os.path.join(td, "stream.jsonl"), "rb") as f, \
                open(os.path.join(ref_td, "stream.jsonl"), "rb") as g:
            assert f.read() == g.read(), f"{label} stream.jsonl differs"
    validate_trace(json.loads(ref))


def test_live_offline_and_viz_trace_identical(tmp_path):
    """The during-run writer, the offline CLI replay, and the VizServer
    /trace endpoint emit the same bytes for the same run."""
    td = str(tmp_path)
    monitor = _run_monitor(
        td, export_trace=os.path.join(td, "trace_live.json"))
    viz_bytes = VizServer(monitor).trace()
    monitor.close()
    with open(os.path.join(td, "trace_live.json"), "rb") as f:
        live = f.read()
    offline = _offline_bytes(td)
    assert live == offline == viz_bytes
    validate_trace(json.loads(live))


# ----------------------------------------------------- provenance windows
def test_provenance_window_export(tmp_path):
    monitor = _run_monitor(str(tmp_path), provdb_shards=2)
    monitor.close()
    docs = load_provenance_docs(str(tmp_path))
    assert docs and docs == sorted(docs, key=lambda d: d["seq"])
    buf = io.StringIO()
    render_provenance_trace(docs, out=buf)
    doc = json.loads(buf.getvalue())
    counts = validate_trace(doc)
    assert counts["instants"] >= len(docs)  # one anomaly marker per window
    inst = [e for e in doc["traceEvents"]
            if e["ph"] == "i" and e["name"] == "anomaly"]
    assert {e["args"]["prov_seq"] for e in inst} == {d["seq"] for d in docs}
    # filtered query narrows the windows
    one = load_provenance_docs(str(tmp_path), rank=docs[0]["rank"])
    assert one and all(d["rank"] == docs[0]["rank"] for d in one)


def test_provenance_export_topology_agnostic(tmp_path):
    """Same windows bytes whether the docs came from 1 or 4 shard files."""
    outs = []
    for S in (1, 4):
        td = str(tmp_path / f"s{S}")
        os.makedirs(td)
        monitor = _run_monitor(td, provdb_shards=S)
        monitor.close()
        buf = io.StringIO()
        render_provenance_trace(load_provenance_docs(td), out=buf)
        outs.append(buf.getvalue())
    assert outs[0] == outs[1]


# ----------------------------------------------------------------- the CLI
def test_cli_end_to_end(tmp_path, capsys):
    td = str(tmp_path)
    monitor = _run_monitor(td)
    monitor.close()
    out = os.path.join(td, "trace.json")
    assert export_main([td, "-o", out]) == 0
    with open(out) as f:
        doc = json.load(f)  # json.load-validates smoke on real output
    validate_trace(doc)
    assert export_main(["--validate", out]) == 0
    assert json.loads(capsys.readouterr().out)["durations"] > 0
    # gzip output is deterministic and decodes to the same bytes
    gz1, gz2 = os.path.join(td, "a.json.gz"), os.path.join(td, "b.json.gz")
    assert export_main([td, "-o", gz1, "--gzip"]) == 0
    assert export_main([td, "-o", gz2, "--gzip"]) == 0
    with open(gz1, "rb") as f, open(gz2, "rb") as g:
        assert f.read() == g.read()
    with gzip.open(gz1, "rb") as f, open(out, "rb") as g:
        assert f.read() == g.read()
    # provenance mode, incl. gzip output under a .json name: validation
    # sniffs the gzip magic instead of trusting the suffix
    pout = os.path.join(td, "prov.json")
    assert export_main([td, "--provenance", "-o", pout]) == 0
    validate_trace(pout)
    assert export_main([td, "--provenance", "-o", pout, "--gzip"]) == 0
    assert export_main(["--validate", pout]) == 0


def test_stream_reader_roundtrip(tmp_path):
    """iter_stream_frames reconstructs the kept records exactly."""
    td = str(tmp_path)
    monitor = _run_monitor(td)
    kept = {k: v.copy() for k, v in monitor.kept.items()}
    meta = dict(monitor.frame_meta)
    monitor.close()
    n = 0
    for fr in iter_stream_frames(os.path.join(td, "stream.jsonl")):
        key = (fr["rank"], fr["step"])
        assert np.array_equal(fr["records"], kept[key])
        assert (fr["ts"], fr["n_records"], fr["n_anomalies"]) == meta[key]
        n += 1
    assert n == len(kept)


def test_query_live_endpoints_matches_files(tmp_path):
    """The --endpoints live path (raw prov.query, no configure) returns the
    same docs the shard files hold, rendered to the same bytes."""
    from repro.export.provenance_export import query_live_endpoints
    from repro.launch.shard_server import LocalShardHost

    td = str(tmp_path)
    with LocalShardHost(2, kind="prov") as host:
        monitor = _run_monitor(td, provdb_transport="socket",
                               shard_endpoints=host.endpoints)
        monitor.provdb.drain()
        # query the *running* job's workers, then compare to its own view
        live = query_live_endpoints(host.endpoints)
        assert live == monitor.provdb.query()
        sev = query_live_endpoints(host.endpoints, min_severity=1)
        assert sev == monitor.provdb.query(min_severity=1)
        monitor.close()
    file_docs = load_provenance_docs(td)
    assert live == file_docs
    bufs = []
    for docs in (live, file_docs):
        buf = io.StringIO()
        render_provenance_trace(docs, out=buf)
        bufs.append(buf.getvalue())
    assert bufs[0] == bufs[1]


def test_torn_stream_tail_exports_prefix(tmp_path):
    """A stream.jsonl cut mid-line (killed run) replays its complete prefix."""
    td = str(tmp_path)
    monitor = _run_monitor(td)
    monitor.close()
    path = os.path.join(td, "stream.jsonl")
    whole = list(iter_stream_frames(path))
    with open(path, "rb") as f:
        data = f.read()
    lines = data.splitlines(keepends=True)
    with open(path, "wb") as f:
        f.writelines(lines[:-1])
        f.write(lines[-1][: len(lines[-1]) // 2])  # torn final line
    torn = list(iter_stream_frames(path))
    assert len(torn) == len(whole) - 1
    for a, b in zip(torn, whole):
        assert np.array_equal(a["records"], b["records"])
    buf = io.StringIO()
    export_stream(path, out=buf)  # and the trace still validates
    validate_trace(json.loads(buf.getvalue()))


# ------------------------------------------------------- comm flow pairing
def _mk_doc(seq, rank, comm, ts0=0):
    """Minimal provenance doc with the given comm events."""
    from repro.core.events import EXEC_RECORD_DTYPE

    anomaly = {f: 0 for f in EXEC_RECORD_DTYPE.names}
    anomaly.update(rank=rank, tid=0, fid=2, entry=ts0, exit=ts0 + 50,
                   runtime=50, depth=1, label=1)
    return {"seq": seq, "rank": rank, "step": 0, "severity": 2,
            "anomaly": anomaly, "call_stack": [], "neighbors": [],
            "comm": comm}


def _comm(ctype, partner, ts, nbytes=64, tag=5, tid=0):
    return {"ctype": ctype, "partner": partner, "ts": ts, "nbytes": nbytes,
            "tag": tag, "tid": tid}


def _render(docs):
    buf = io.StringIO()
    render_provenance_trace(docs, out=buf)
    return json.loads(buf.getvalue())


def test_comm_flow_pairing_send_recv():
    """A SEND on rank 0 and its RECV on rank 1 become one s/f flow pair at
    the two comm instants' timestamps."""
    docs = [
        _mk_doc(0, 0, [_comm(0, 1, 100)]),      # SEND 0→1 at ts 100
        _mk_doc(1, 1, [_comm(1, 0, 120)]),      # RECV on 1 from 0 at ts 120
    ]
    doc = _render(docs)
    counts = validate_trace(doc)
    assert counts["flows"] == 1
    s = [e for e in doc["traceEvents"] if e["ph"] == "s"][0]
    f_ = [e for e in doc["traceEvents"] if e["ph"] == "f"][0]
    assert (s["ts"], s["pid"]) == (100, 0)
    assert (f_["ts"], f_["pid"]) == (120, 1)
    assert s["id"] == f_["id"] and s["cat"] == f_["cat"] == "comm"


def test_comm_flow_no_false_pairs():
    """No arrow when ts ordering, nbytes, or tag rule the match out — and
    the unmatched instants still render."""
    cases = [
        [_mk_doc(0, 0, [_comm(0, 1, 200)]), _mk_doc(1, 1, [_comm(1, 0, 120)])],
        [_mk_doc(0, 0, [_comm(0, 1, 100, nbytes=8)]),
         _mk_doc(1, 1, [_comm(1, 0, 120, nbytes=64)])],
        [_mk_doc(0, 0, [_comm(0, 1, 100, tag=1)]),
         _mk_doc(1, 1, [_comm(1, 0, 120, tag=2)])],
    ]
    for docs in cases:
        doc = _render(docs)
        assert validate_trace(doc)["flows"] == 0
        assert sum(e["name"].startswith("comm") for e in doc["traceEvents"]
                   if e["ph"] == "i") == 2


def test_comm_flow_fifo_and_dedup():
    """Two in-flight messages on one channel pair FIFO; an event captured by
    two overlapping windows flows only once."""
    docs = [
        _mk_doc(0, 0, [_comm(0, 1, 100), _comm(0, 1, 110)]),
        _mk_doc(1, 1, [_comm(1, 0, 105), _comm(1, 0, 130)]),
        _mk_doc(2, 0, [_comm(0, 1, 100)]),  # duplicate SEND, another window
    ]
    doc = _render(docs)
    counts = validate_trace(doc)
    assert counts["flows"] == 2
    ss = sorted((e["id"], e["ts"]) for e in doc["traceEvents"] if e["ph"] == "s")
    ff = sorted((e["id"], e["ts"]) for e in doc["traceEvents"] if e["ph"] == "f")
    # FIFO: first send → first recv, second send → second recv
    assert [ts for _i, ts in ss] == [100, 110]
    assert [ts for _i, ts in ff] == [105, 130]
    assert _render(docs) == doc  # deterministic


# --------------------------------------------------- stream append resume
def test_stream_writer_append_resume(tmp_path):
    """append=True resumes: one header, prior frames preserved byte-for-byte,
    fid dedup state recovered so names aren't re-announced."""
    from repro.export.record_stream import RecordStreamWriter

    path = str(tmp_path / "stream.jsonl")
    names = {1: "main", 2: "solve"}
    w = RecordStreamWriter(path)
    w.add_frame(0, 0, _recs([(1, 0, 10, 1), (2, 2, 8, 2)]), names,
                n_records=2, ts=10)
    w.close()
    with open(path, "rb") as f:
        seg1 = f.read()
    w = RecordStreamWriter(path, append=True)
    w.add_frame(0, 1, _recs([(2, 12, 18, 2)]), names, n_records=1, ts=18)
    w.close()
    with open(path, "rb") as f:
        data = f.read()
    assert data.startswith(seg1)  # prior frames untouched
    lines = [json.loads(line) for line in data.splitlines()]
    assert sum(d["type"] == "header" for d in lines) == 1
    frames = [d for d in lines if d["type"] == "frame"]
    assert [(d["rank"], d["step"]) for d in frames] == [(0, 0), (0, 1)]
    assert frames[0]["new_funcs"] == {"1": "main", "2": "solve"}
    assert frames[1]["new_funcs"] == {}  # dedup state recovered, not reset
    assert len(list(iter_stream_frames(path))) == 2


def test_stream_append_truncates_torn_tail(tmp_path):
    """Resuming over a torn tail (killed mid-write) drops only the torn
    line; appended frames continue the stream cleanly."""
    from repro.export.record_stream import RecordStreamWriter

    path = str(tmp_path / "stream.jsonl")
    w = RecordStreamWriter(path)
    w.add_frame(0, 0, _recs([(1, 0, 10, 1)]), {1: "main"}, n_records=1, ts=10)
    w.add_frame(0, 1, _recs([(1, 12, 20, 1)]), {1: "main"}, n_records=1, ts=20)
    w.close()
    with open(path, "rb") as f:
        data = f.read()
    lines = data.splitlines(keepends=True)
    with open(path, "wb") as f:
        f.writelines(lines[:-1])
        f.write(lines[-1][: len(lines[-1]) // 2])  # tear the last frame
    w = RecordStreamWriter(path, append=True)
    w.add_frame(0, 2, _recs([(1, 22, 30, 1)]), {1: "main"}, n_records=1, ts=30)
    w.close()
    frames = list(iter_stream_frames(path))
    assert [(f["rank"], f["step"]) for f in frames] == [(0, 0), (0, 2)]
    # and the whole file is clean JSONL again (no torn fragment mid-file)
    with open(path, "rb") as f:
        for line in f.read().splitlines():
            json.loads(line)


def test_stream_append_empty_or_missing_starts_fresh(tmp_path):
    """append=True over a missing or empty file degrades to a fresh stream
    (header written once)."""
    from repro.export.record_stream import RecordStreamWriter

    for name, pre in (("missing.jsonl", None), ("empty.jsonl", b"")):
        path = str(tmp_path / name)
        if pre is not None:
            with open(path, "wb") as f:
                f.write(pre)
        w = RecordStreamWriter(path, append=True)
        w.add_frame(0, 0, _recs([(1, 0, 10, 1)]), {1: "main"},
                    n_records=1, ts=10)
        w.close()
        with open(path) as f:
            lines = f.read().splitlines()
        assert json.loads(lines[0])["type"] == "header"
        assert len(list(iter_stream_frames(path))) == 1


def test_monitor_stream_resume_matches_prov_append(tmp_path):
    """ROADMAP regression: a prov_append resume must append the record
    stream too — both segments replay, and the trace still validates."""
    from repro.core.sim import WorkloadGenerator, nwchem_like

    td = str(tmp_path)
    spec = nwchem_like(anomaly_rate=0.02)
    for f in spec.funcs.values():
        f.anomaly_scale = 40.0

    def _segment(step_lo, step_hi, append):
        gen = WorkloadGenerator(spec, n_ranks=2, seed=3)
        monitor = ChimbukoMonitor(
            num_funcs=len(gen.registry), registry=gen.registry, min_samples=20,
            prov_path=os.path.join(td, "provenance.jsonl"),
            stream_path=os.path.join(td, "stream.jsonl"),
            prov_append=append, run_info={"timestamp": 0.0},
        )
        for step in range(step_lo, step_hi):
            for rank in range(2):
                frame, _ = gen.frame(rank, step)
                monitor.ingest(frame)
        monitor.close()

    _segment(0, 5, append=False)
    n_seg1 = len(list(iter_stream_frames(os.path.join(td, "stream.jsonl"))))
    _segment(5, 10, append=True)  # the restart path
    frames = list(iter_stream_frames(os.path.join(td, "stream.jsonl")))
    assert n_seg1 == 10 and len(frames) == 20  # both segments present
    steps = sorted({f["step"] for f in frames})
    assert steps == list(range(10))
    validate_trace(json.loads(_offline_bytes(td)))


def test_path_family_handles_shard_in_dirname(tmp_path):
    """A '.shard' substring in the directory or base name must not
    truncate the family root."""
    from repro.export.provenance_export import provenance_path_family

    d = tmp_path / "run.shard_sweep"
    d.mkdir()
    (d / "provenance.jsonl").write_text("{}\n")
    (d / "provenance.shard1.jsonl").write_text("{}\n")
    fam = provenance_path_family(str(d))
    assert fam == [str(d / "provenance.jsonl"),
                   str(d / "provenance.shard1.jsonl")]
    # shard-file input resolves the same family
    assert provenance_path_family(str(d / "provenance.shard1.jsonl")) == fam
