"""Per-architecture smoke tests (reduced configs) + cache-consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import synthetic_batch
from repro.models import model as M
from repro.models.common import init_params
from repro.models.moe import moe_block


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.smoke(arch)
    B, S = 2, 32
    params = init_params(cfg, jax.random.key(0))
    batch = synthetic_batch(cfg, B, S, seed=1)

    logits = M.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    def loss_fn(p):
        return M.loss_and_metrics(cfg, p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), f"{arch}: bad grads"
    # at least one non-zero gradient per layer position
    gnorm = sum(float(jnp.abs(g).sum()) for g in leaves)
    assert gnorm > 0


@pytest.mark.parametrize(
    "arch",
    ["gemma2-2b", "minicpm3-4b", "falcon-mamba-7b", "jamba-v0.1-52b", "qwen2-vl-2b",
     "h2o-danube-3-4b"],
)
def test_decode_matches_forward(arch):
    """prefill(S-1) + decode(last) must equal full forward's last logits.

    f32 compute isolates cache/masking logic from bf16 reordering noise
    (absorbed-MLA and chunked-scan reorder reductions materially in bf16).
    Ample MoE capacity isolates it from drop-policy differences (a 15-token
    prefill and a 16-token forward legitimately drop different tokens).
    """
    cfg = dataclasses.replace(
        configs.smoke(arch), compute_dtype=jnp.float32, moe_capacity_factor=16.0
    )
    B, S = 2, 16  # S < smoke window (32): ring buffer not wrapped here
    params = init_params(cfg, jax.random.key(1))
    batch = synthetic_batch(cfg, B, S, seed=2)
    if cfg.modality == "vision_stub":
        batch.pop("pos3")  # use text-degenerate M-RoPE so decode can continue it
        batch.pop("visual_embeds")
    full = M.forward(cfg, params, batch)

    pre_batch = {k: v[:, : S - 1] if v.ndim >= 2 and v.shape[1] == S else v
                 for k, v in batch.items() if k != "labels"}
    _, cache = M.prefill(cfg, params, pre_batch, max_seq=S)
    logits, cache = M.decode_step(cfg, params, cache, batch["tokens"][:, S - 1 :])
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full[:, -1], np.float32),
        rtol=1e-4, atol=1e-3,
    )


def test_swa_ring_buffer_consistency():
    """Decode past the window: ring buffer must equal windowed reference."""
    cfg = configs.smoke("h2o-danube-3-4b")
    cfg = dataclasses.replace(cfg, window=8, compute_dtype=jnp.float32)
    B, S = 1, 24
    params = init_params(cfg, jax.random.key(3))
    batch = synthetic_batch(cfg, B, S, seed=3)
    full = M.forward(cfg, params, batch)  # SWA masking inside
    # decode token-by-token from scratch
    cache = M.init_cache(cfg, B, max_seq=S)
    outs = []
    for t in range(S):
        logits, cache = M.decode_step(cfg, params, cache, batch["tokens"][:, t : t + 1])
        outs.append(np.asarray(logits[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full, np.float32), rtol=1e-4, atol=1e-3)


def test_moe_matches_dense_routing_reference():
    """Sort-based capacity dispatch == naive per-token loop (ample capacity)."""
    cfg = dataclasses.replace(
        configs.smoke("granite-moe-1b-a400m"), moe_capacity_factor=8.0
    )
    from repro.models.common import init_layer_params

    p = init_layer_params(cfg, cfg.layout[0], jax.random.key(4))
    sub = {k: p[k] for k in ("router", "moe_gate", "moe_up", "moe_down")}
    x = jax.random.normal(jax.random.key(5), (2, 8, cfg.d_model), jnp.float32)
    out = moe_block(sub, x, cfg, None)

    # naive reference
    xt = np.asarray(x.reshape(-1, cfg.d_model), np.float64)
    router = np.asarray(sub["router"], np.float64)
    logits = xt @ router
    probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    ref = np.zeros_like(xt)
    for i in range(xt.shape[0]):
        top = np.argsort(probs[i])[::-1][: cfg.moe_topk]
        w = probs[i, top] / probs[i, top].sum()
        for e, we in zip(top, w):
            g = xt[i] @ np.asarray(sub["moe_gate"][e], np.float64)
            u = xt[i] @ np.asarray(sub["moe_up"][e], np.float64)
            h = (g / (1 + np.exp(-g))) * u
            ref[i] += we * (h @ np.asarray(sub["moe_down"][e], np.float64))
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, cfg.d_model), np.float64), ref, rtol=2e-3, atol=2e-3
    )


def test_mamba_scan_matches_sequential():
    """Chunked associative scan == naive per-step recurrence."""
    from repro.models.mamba import _ssm_scan_chunked

    rng = np.random.default_rng(0)
    B, S, di, st = 2, 16, 4, 3
    a = np.exp(-rng.uniform(0.1, 1.0, (B, S, di, st))).astype(np.float32)
    b = rng.normal(0, 1, (B, S, di, st)).astype(np.float32)
    C = rng.normal(0, 1, (B, S, st)).astype(np.float32)
    y, h_last = _ssm_scan_chunked(jnp.asarray(a), jnp.asarray(b), jnp.asarray(C), chunk=4)
    h = np.zeros((B, di, st), np.float64)
    ys = np.zeros((B, S, di))
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        ys[:, t] = np.einsum("bds,bs->bd", h, C[:, t])
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), h, rtol=1e-4, atol=1e-4)


def test_attention_chunked_matches_direct():
    from repro.models import layers as L

    rng = np.random.default_rng(1)
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, KV, hd)), jnp.float32)
    for causal, window, cap in [(True, 0, 0.0), (True, 16, 0.0), (False, 0, 0.0), (True, 0, 30.0)]:
        direct = L.attention_direct(q, k, v, causal=causal, window=window, cap=cap)
        chunked = L.attention_chunked(
            q, k, v, causal=causal, window=window, cap=cap, chunk_q=16, chunk_k=16
        )
        np.testing.assert_allclose(
            np.asarray(chunked), np.asarray(direct), rtol=2e-5, atol=2e-5,
            err_msg=f"causal={causal} window={window} cap={cap}",
        )


def test_param_count_analytic_vs_actual():
    for arch in ("gemma-2b", "granite-moe-1b-a400m", "falcon-mamba-7b"):
        cfg = configs.smoke(arch)
        params = init_params(cfg, jax.random.key(0))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        assert actual == cfg.n_params(), (arch, actual, cfg.n_params())


def test_full_config_param_counts():
    """Full (published) configs land near their nameplate sizes."""
    expect = {
        "falcon-mamba-7b": (6.5e9, 8.5e9),
        "qwen3-moe-30b-a3b": (28e9, 32e9),
        "jamba-v0.1-52b": (49e9, 56e9),
        "granite-moe-1b-a400m": (1.0e9, 1.7e9),
        "gemma2-2b": (2.2e9, 3.5e9),
        "gemma-2b": (2.0e9, 3.0e9),
        "minicpm3-4b": (3.5e9, 5.0e9),
        "h2o-danube-3-4b": (3.5e9, 4.6e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
        "qwen2-vl-2b": (1.2e9, 2.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
