"""Dry-run machinery: HLO parsing, roofline math, probe semantics, mini-mesh."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.compat import cost_analysis
from repro.launch import roofline as R


def test_cost_analysis_counts_loop_bodies_once():
    """The documented XLA behavior probe-mode corrects for."""

    def f(x, n):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=n)[0]

    x = jnp.ones((256, 256))
    f4 = cost_analysis(jax.jit(f, static_argnums=1).lower(x, 4).compile())["flops"]
    f8 = cost_analysis(jax.jit(f, static_argnums=1).lower(x, 8).compile())["flops"]
    assert f4 == f8  # loop body counted once regardless of trip count
    # unrolled scan counts every iteration
    def fu(x, n):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=n, unroll=True)[0]

    u8 = cost_analysis(jax.jit(fu, static_argnums=1).lower(x, 8).compile())["flops"]
    assert u8 >= 7.5 * f4 / 8 * 8  # ≈ 8 bodies counted


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[16,4096]{1,0} all-gather(bf16[16,256]{1,0} %p), dims={1}
  %ar.1 = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%add
  %ars = f32[512]{0} all-reduce-start(f32[512]{0} %y), to_apply=%add
  %rs = (f32[128]{0}, f32[128]{0}) reduce-scatter(f32[1024]{0} %a, f32[1024]{0} %b), dims={0}
  %cp = u8[64]{0} collective-permute(u8[64]{0} %z), source_target_pairs={{0,1}}
"""
    out = R.collective_bytes(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["result_bytes"] == 16 * 4096 * 2
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["result_bytes"] == 1024 * 4 + 512 * 4
    assert out["all-reduce"]["wire_bytes"] == 2 * (1024 * 4 + 512 * 4)
    assert out["reduce-scatter"]["result_bytes"] == 2 * 128 * 4
    assert out["collective-permute"]["result_bytes"] == 64


def test_roofline_terms_math():
    t = R.roofline_terms(197e12, 819e9, 50e9)  # exactly 1s each
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 1.0) < 1e-9
    t2 = R.roofline_terms(197e12, 0.0, 0.0)
    assert t2["dominant"] == "compute"
    assert t2["compute_fraction_of_bound"] == 1.0


def test_model_flops_modes():
    cfg = configs.get_config("gemma-2b")
    n = cfg.n_active_params()
    assert R.model_flops(cfg, "train", 4, 128) == 6.0 * n * 512
    assert R.model_flops(cfg, "prefill", 4, 128) == 2.0 * n * 512
    assert R.model_flops(cfg, "decode", 4, 128) == 2.0 * n * 4
    moe = configs.get_config("qwen3-moe-30b-a3b")
    assert moe.n_active_params() < 0.2 * moe.n_params()  # 3B active of 30B


def test_memory_floor_sane():
    cfg = configs.get_config("gemma-2b")
    f_train = R.analytic_memory_floor(cfg, "train", 256, 4096, 256, 1)
    f_dec = R.analytic_memory_floor(cfg, "decode", 128, 32768, 256, 1)
    assert f_train > f_dec  # training moves far more bytes
    assert 1e8 < f_dec < 1e12
    # decode must include weight reads: at least 2·Na/16 bytes
    assert f_dec > 2 * cfg.n_active_params() / 16


_PROBE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax
from repro import configs
from repro.launch.steps import StepOptions, make_cell
from repro.launch.dryrun import probe_costs

from repro.compat import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
configs.SHAPES["mini_train"] = configs.ShapeCell("mini_train", 64, 8, "train")
cfg = configs.smoke("gemma2-2b")  # period 2, smoke n_layers = 4 (2 periods)
probe = probe_costs(cfg, "mini_train", mesh, {}, 1)

# ground truth: full model with every scan unrolled, cost counted directly
full = make_cell(cfg, "mini_train", mesh, StepOptions(probe=True, microbatch=1))
from repro.compat import cost_analysis
ca = cost_analysis(full.lower().compile())
direct = float(ca["flops"])
extrap = probe["flops"]
rel = abs(extrap - direct) / direct
assert rel < 0.02, (extrap, direct, rel)
print("PROBE_EXTRAPOLATION_OK", extrap, direct)
"""


def test_probe_extrapolation_matches_unrolled():
    """C(1) + (NP−1)(C(2)−C(1)) == fully-unrolled cost (affine exactness)."""
    r = subprocess.run(
        [sys.executable, "-c", _PROBE_SCRIPT], capture_output=True, text=True,
        timeout=560, env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "PROBE_EXTRAPOLATION_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-3000:]


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import dataclasses
from repro import configs
from repro.launch.steps import StepOptions, make_cell
from repro.compat import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
configs.SHAPES["mini"] = configs.ShapeCell("mini", 64, 8, "train")
configs.SHAPES["mini_dec"] = configs.ShapeCell("mini_dec", 64, 8, "decode")
for arch in ("jamba-v0.1-52b", "qwen3-moe-30b-a3b", "minicpm3-4b"):
    cfg = configs.smoke(arch)
    for shape in ("mini", "mini_dec"):
        cell = make_cell(cfg, shape, mesh, StepOptions(ce_chunk=32))
        cell.lower().compile()
print("MINI_MESH_OK")
"""


def test_mini_mesh_cells_compile():
    """Representative archs × (train, decode) lower+compile on a 3-axis mesh."""
    r = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT], capture_output=True, text=True,
        timeout=560, env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "MINI_MESH_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-3000:]


def test_cell_applicability_table():
    cells = list(configs.all_cells())
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 32  # 8 documented skips (DESIGN.md §5)
    skipped = {(a, s) for a, s, ok, _ in cells if not ok}
    assert ("hubert_xlarge", "decode_32k") in skipped
    assert ("hubert_xlarge", "long_500k") in skipped
    assert ("gemma_2b", "long_500k") in skipped
    assert ("falcon_mamba_7b", "long_500k") not in skipped
    assert ("jamba_v01_52b", "long_500k") not in skipped
    assert ("h2o_danube3_4b", "long_500k") not in skipped
