"""repro.lint: fixture-driven rule tests, suppressions, baseline, CLI,
and the runtime thread-ownership sanitizer.

Bad fixtures under ``tests/data/lint_fixtures/`` carry ``# EXPECT: <rule>``
markers on each hazardous line; the tests assert the analyzer reports
exactly that (rule, line) set.  Good twins must produce zero findings —
every one doubles as a false-positive regression test.
"""
import json
import os
import re
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.lint import RULE_IDS, baseline as bl, runtime as san
from repro.lint.rules import analyze

FIXTURES = Path(__file__).parent / "data" / "lint_fixtures"
REPO = Path(__file__).resolve().parents[1]
_EXPECT_RE = re.compile(r"#\s*EXPECT:\s*([a-z-]+)")

BAD_FIXTURES = sorted(p.name for p in FIXTURES.glob("*_bad.py"))
GOOD_FIXTURES = sorted(p.name for p in FIXTURES.glob("*_good.py"))


def expected_hits(path: Path):
    out = set()
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        m = _EXPECT_RE.search(line)
        if m:
            out.add((m.group(1), lineno))
    return out


def actual_hits(path: Path):
    return {(f.rule, f.line) for f in analyze(str(path))}


# --------------------------------------------------------------- rule tests
def test_fixture_inventory():
    """Every rule family has at least one bad/good fixture pair, and every
    EXPECT marker names a real rule id."""
    assert len(BAD_FIXTURES) >= 6 and len(GOOD_FIXTURES) >= 6
    covered = set()
    for name in BAD_FIXTURES:
        for rule, _line in expected_hits(FIXTURES / name):
            assert rule in RULE_IDS, f"{name}: unknown rule {rule!r}"
            covered.add(rule)
    # Families: loop-hazard, lockset, determinism all represented.
    assert {"loop-blocking-sleep", "loop-blocking-io", "loop-blocking-sync",
            "loop-blocking-socket", "loop-heavy-handler",
            "lockset-mixed", "lockset-counter",
            "det-unordered-iter", "det-wallclock", "det-random"} <= covered


@pytest.mark.parametrize("name", BAD_FIXTURES)
def test_bad_fixture_exact_hits(name):
    path = FIXTURES / name
    expected = expected_hits(path)
    assert expected, f"{name} has no EXPECT markers"
    assert actual_hits(path) == expected


@pytest.mark.parametrize("name", GOOD_FIXTURES)
def test_good_fixture_clean(name):
    assert analyze(str(FIXTURES / name)) == []


def test_rule_filter_restricts_output():
    path = FIXTURES / "det_bad.py"
    only = analyze(str(path), rules=["det-wallclock"])
    assert [f.rule for f in only] == ["det-wallclock"]


def test_findings_carry_symbol_and_message():
    (f,) = analyze(str(FIXTURES / "loop_sleep_bad.py"))
    assert f.symbol == "PacedServer._tick"
    assert "time.sleep" in f.message
    assert f.path == "loop_sleep_bad.py"


# ------------------------------------------------------------- suppressions
def test_suppressions_silence_line_def_and_bare():
    assert analyze(str(FIXTURES / "suppress_fixture.py")) == []


def test_suppression_is_rule_scoped():
    """A line-level ignore for one rule must not silence a different rule
    on the same line."""
    src = FIXTURES / "loop_sleep_bad.py"
    text = src.read_text()
    patched = text.replace(
        "time.sleep(0.01)  # EXPECT: loop-blocking-sleep",
        "time.sleep(0.01)  # lint: ignore[det-wallclock]",
    )
    assert patched != text
    tmp = FIXTURES / "_tmp_scoped.py"
    tmp.write_text(patched)
    try:
        assert {f.rule for f in analyze(str(tmp))} == {"loop-blocking-sleep"}
    finally:
        tmp.unlink()


# ------------------------------------------------------------------ baseline
def test_committed_baseline_matches_fresh_run():
    """Self-check: a fresh analysis of src/ must be exactly covered by the
    committed baseline — no new findings, no stale entries.  This is the
    same invariant the CI gate enforces."""
    findings = analyze(str(REPO / "src"))
    baseline = bl.load(str(REPO / "tools" / "lint_baseline.json"))
    new, stale = bl.apply(findings, baseline)
    assert new == [] and stale == []


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({
        "version": 1,
        "entries": [{"rule": "lockset-mixed", "path": "x.py",
                     "symbol": "C.m", "count": 1, "justification": "  "}],
    }))
    with pytest.raises(bl.BaselineError):
        bl.load(str(p))


def test_baseline_apply_counts_and_staleness(tmp_path):
    findings = analyze(str(FIXTURES / "lockset_bad.py"))
    assert len(findings) == 2
    entries = [
        {"rule": f.rule, "path": f.path, "symbol": f.symbol,
         "count": 1, "justification": "fixture"}
        for f in findings
    ] + [{"rule": "det-random", "path": "gone.py", "symbol": "f",
          "count": 1, "justification": "fixture"}]
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"version": bl.VERSION, "entries": entries}))
    loaded = bl.load(str(p))
    new, stale = bl.apply(findings, loaded)
    assert new == []
    assert [(e["rule"], e["path"]) for e in stale] == [("det-random", "gone.py")]
    # A second hit on a count-1 entry is NEW, not absorbed.
    new2, _ = bl.apply(list(findings) + [findings[0]], loaded)
    assert [f.key() for f in new2] == [findings[0].key()]


# ------------------------------------------------------------------- CLI
def _run_cli(*args):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.lint", *args],
        capture_output=True, text=True, env=env, cwd=str(REPO),
    )


def test_cli_clean_target_exits_zero():
    proc = _run_cli(str(FIXTURES / "loop_sleep_good.py"), "--baseline", "none")
    assert proc.returncode == 0, proc.stderr


def test_cli_findings_exit_2_and_json_report(tmp_path):
    report = tmp_path / "lint_report.json"
    proc = _run_cli(str(FIXTURES / "det_bad.py"), "--baseline", "none",
                    "--format", "json", "--report", str(report))
    assert proc.returncode == 2
    payload = json.loads(proc.stdout)
    assert {f["rule"] for f in payload["findings"]} == {
        "det-unordered-iter", "det-wallclock", "det-random"}
    on_disk = json.loads(report.read_text())
    assert on_disk["findings"] == payload["findings"]


def test_cli_text_format_lists_path_line_rule():
    proc = _run_cli(str(FIXTURES / "loop_sleep_bad.py"), "--baseline", "none")
    assert proc.returncode == 2
    assert re.search(r"loop_sleep_bad\.py:\d+: loop-blocking-sleep:",
                     proc.stdout)


def test_cli_bad_invocation_exits_3(tmp_path):
    proc = _run_cli(str(tmp_path / "nope_does_not_exist"))
    assert proc.returncode == 3


def test_cli_write_baseline_roundtrip(tmp_path):
    out = tmp_path / "baseline.json"
    proc = _run_cli(str(FIXTURES / "lockset_bad.py"), "--baseline", "none",
                    "--write-baseline", str(out))
    assert proc.returncode == 0  # documented: write the skeleton and exit 0
    skeleton = json.loads(out.read_text())
    assert all("TODO" in e["justification"] for e in skeleton["entries"])
    # Justify every entry, then re-run against the baseline: exit 0.
    for e in skeleton["entries"]:
        e["justification"] = "fixture: intentional"
    out.write_text(json.dumps(skeleton))
    proc2 = _run_cli(str(FIXTURES / "lockset_bad.py"), "--baseline", str(out))
    assert proc2.returncode == 0, proc2.stderr + proc2.stdout


def test_cli_gate_on_src_is_green():
    """The exact CI gate invocation must pass on the committed tree."""
    proc = _run_cli("src/")
    assert proc.returncode == 0, proc.stderr + proc.stdout


# ------------------------------------------------------- runtime sanitizer
class _Owner:
    def __init__(self, thread):
        self._loop_thread = thread


def test_sanitizer_loop_assert_passes_on_loop_thread():
    err = []

    def body():
        try:
            san.assert_loop_thread(_Owner(threading.current_thread()))
        except Exception as e:  # pragma: no cover - fails the assert below
            err.append(e)

    t = threading.Thread(target=body)
    t.start()
    t.join()
    assert err == []


def test_sanitizer_loop_assert_raises_off_thread():
    t = threading.Thread(target=lambda: None)
    t.start()
    t.join()
    with pytest.raises(san.ThreadOwnershipError, match="loop-owned"):
        san.assert_loop_thread(_Owner(t))


def test_sanitizer_worker_assert_raises_on_loop_thread():
    with pytest.raises(san.ThreadOwnershipError, match="event-loop thread"):
        san.assert_worker_thread(_Owner(threading.current_thread()))
    # ... and passes for any other thread's owner.
    t = threading.Thread(target=lambda: None)
    t.start()
    t.join()
    san.assert_worker_thread(_Owner(t))


def test_sanitizer_noops_before_loop_starts():
    san.assert_loop_thread(_Owner(None))
    san.assert_worker_thread(_Owner(None))


def test_sanitizer_enabled_in_suite():
    """conftest.py exports REPRO_SANITIZE=1 before any repro import, so the
    whole suite runs with ownership checks armed."""
    assert os.environ.get("REPRO_SANITIZE") == "1"
    assert san.ENABLED


def test_sanitizer_enable_disable_toggle():
    orig = san.ENABLED
    try:
        san.disable()
        assert not san.ENABLED
        san.enable()
        assert san.ENABLED
    finally:
        (san.enable if orig else san.disable)()
