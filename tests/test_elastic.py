"""Elasticity: a run checkpointed at one mesh width continues at another."""
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools, jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.checkpoint import ckpt as CK
from repro.data.pipeline import DataShard, SyntheticStream
from repro.launch import sharding as SH
from repro.launch.steps import StepOptions, build_train_step, make_shard_ctx, make_train_state
from repro.optim.adamw import OptConfig

from repro.compat import make_mesh

cfg = configs.smoke("gemma-2b")
opts = StepOptions(ce_chunk=512, opt=OptConfig(peak_lr=1e-3, warmup_steps=5))
GB, SEQ = 8, 32
stream = SyntheticStream(cfg, DataShard(0, 1, GB), SEQ, seed=3)

def run_steps(mesh, state, lo, hi):
    ctx = make_shard_ctx(cfg, mesh, GB, opts)
    step_fn = jax.jit(build_train_step(cfg, ctx, opts, microbatch=1))
    losses = []
    for s in range(lo, hi):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(s).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    return state, losses

# reference: uninterrupted single-device run
state0 = make_train_state(cfg, 0)
_, ref_losses = run_steps(None, make_train_state(cfg, 0), 0, 12)

# phase 1: mesh A = (4 data, 2 model)
mesh_a = make_mesh((4, 2), ("data", "model"))
sh_a = {
    "params": SH.param_shardings(cfg, jax.eval_shape(lambda: state0["params"]), mesh_a),
}
state = make_train_state(cfg, 0)
state, l_a = run_steps(mesh_a, state, 0, 6)
CK.save("/tmp/elastic_ck", 6, state)

# phase 2 ("after node loss"): mesh B = (2 data, 4 model), restored + resharded
mesh_b = make_mesh((2, 4), ("data", "model"))
target = jax.eval_shape(functools.partial(make_train_state, cfg))
shards_b = {
    "params": SH.param_shardings(cfg, target["params"], mesh_b),
    "m": SH.param_shardings(cfg, target["m"], mesh_b),
    "v": SH.param_shardings(cfg, target["v"], mesh_b),
    "step": NamedSharding(mesh_b, P()),
}
step_n, state_b = CK.load("/tmp/elastic_ck", target=target, shardings=shards_b)
assert step_n == 6
_, l_b = run_steps(mesh_b, state_b, 6, 12)

full = l_a + l_b
err = max(abs(x - y) for x, y in zip(full, ref_losses))
assert err < 5e-2, (err, full, ref_losses)
print("ELASTIC_OK", err)
"""


def test_elastic_mesh_rescale():
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=560, env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "ELASTIC_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-3000:]
