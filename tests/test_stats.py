"""Property tests for Pébay streaming moments (paper ref [14])."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import stats as S


def exact_row(xs: np.ndarray) -> np.ndarray:
    return S.batch_moments(np.asarray(xs, np.float64))


values = st.lists(
    st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, width=64),
    min_size=0,
    max_size=200,
)


@given(values, values)
@settings(max_examples=60, deadline=None)
def test_merge_matches_concat(xs, ys):
    a, b = exact_row(np.asarray(xs)), exact_row(np.asarray(ys))
    merged = S.merge_moments(a, b)
    ref = exact_row(np.asarray(xs + ys))
    assert np.isclose(merged[S.N], ref[S.N])
    if ref[S.N] > 0:
        scale = max(abs(ref[S.MEAN]), 1.0)
        assert np.isclose(merged[S.MEAN], ref[S.MEAN], rtol=1e-9, atol=1e-6 * scale)
        assert np.isclose(merged[S.M2], ref[S.M2], rtol=1e-6, atol=1e-3 * scale**2)
        assert np.isclose(merged[S.MIN], ref[S.MIN])
        assert np.isclose(merged[S.MAX], ref[S.MAX])


@given(values, values, values)
@settings(max_examples=40, deadline=None)
def test_merge_associative(xs, ys, zs):
    a, b, c = (exact_row(np.asarray(v)) for v in (xs, ys, zs))
    left = S.merge_moments(S.merge_moments(a, b), c)
    right = S.merge_moments(a, S.merge_moments(b, c))
    assert np.allclose(left[:3], right[:3], rtol=1e-7, atol=1e-4)


@given(values)
@settings(max_examples=40, deadline=None)
def test_higher_moments_match_numpy(xs):
    xs = np.asarray(xs, np.float64)
    if xs.size < 3 or np.ptp(xs) < 1e-9:
        return
    rs = S.RunningStats()
    # push in random chunks to exercise the streaming path
    rng = np.random.default_rng(0)
    splits = np.sort(rng.integers(0, xs.size, size=3))
    for chunk in np.split(xs, splits):
        if chunk.size:
            rs.push_batch(chunk)
    assert np.isclose(rs.mean, xs.mean(), rtol=1e-8, atol=1e-6)
    assert np.isclose(rs.var, xs.var(), rtol=1e-5, atol=1e-3)


def test_stats_table_update_and_merge():
    rng = np.random.default_rng(42)
    fids = rng.integers(0, 8, size=500)
    vals = rng.lognormal(3.0, 1.0, size=500)
    t = S.StatsTable(8)
    # split into 7 frames
    for part in np.array_split(np.arange(500), 7):
        t.update_batch(fids[part], vals[part])
    for f in range(8):
        sel = vals[fids == f]
        assert np.isclose(t.counts()[f], sel.size)
        if sel.size:
            assert np.isclose(t.means()[f], sel.mean(), rtol=1e-9)
            assert np.isclose(t.stds()[f], sel.std(), rtol=1e-6)

    # two-table merge == one table over all data
    t1, t2 = S.StatsTable(8), S.StatsTable(8)
    t1.update_batch(fids[:250], vals[:250])
    t2.update_batch(fids[250:], vals[250:])
    t1.merge(t2)
    assert np.allclose(t1.table[:, : S.M3], t.table[:, : S.M3], rtol=1e-8)


def test_empty_and_growth():
    t = S.StatsTable(2)
    t.update_batch(np.zeros(0, np.int64), np.zeros(0))
    assert t.counts().sum() == 0
    t.grow(5)
    t.update_batch(np.asarray([4]), np.asarray([3.0]))
    assert t.counts()[4] == 1
    r = t.row(4)
    assert r.mean == 3.0 and r.std == 0.0


def test_running_stats_skew_kurtosis():
    rng = np.random.default_rng(7)
    xs = rng.normal(size=20000)
    rs = S.RunningStats()
    rs.push_batch(xs)
    assert abs(rs.skewness) < 0.1
    assert abs(rs.kurtosis) < 0.2
