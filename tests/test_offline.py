"""Offline mode (paper §II-B): archive replay + cross-run comparison."""
import numpy as np
import pytest

from repro.core.offline import RunProfile, compare_runs, replay, report
from repro.core.sim import WorkloadGenerator, nwchem_like
from repro.trace.monitor import ChimbukoMonitor
from repro.trace.stream import FrameStore


def _make_run(tmp_path, name, slow_factor=1.0, steps=25, ranks=3):
    spec = nwchem_like(anomaly_rate=0.004)
    for f in spec.funcs.values():
        f.anomaly_scale = 40.0
    # run B simulates a regression in SP_GTXPBL (the case-study culprit)
    spec.funcs["SP_GTXPBL"].mean_us *= slow_factor
    gen = WorkloadGenerator(spec, n_ranks=ranks, seed=11)
    store = FrameStore(str(tmp_path / name))
    for step in range(steps):
        for rank in range(ranks):
            frame, _ = gen.frame(rank, step)
            store.write(frame)
    return store, gen.registry


def test_replay_equals_online(tmp_path):
    """Offline replay == the online pipeline on the same frames."""
    store, registry = _make_run(tmp_path, "runA")
    # online pass
    online = ChimbukoMonitor(num_funcs=len(registry), registry=registry,
                             min_samples=30)
    for step in range(25):
        for rank in store.ranks():
            online.ingest(store.read(rank, step))
    # offline replay
    offline = replay(store, registry=registry, num_funcs=len(registry),
                     min_samples=30)
    assert offline.summary()["anomalies"] == online.summary()["anomalies"]
    assert offline.summary()["events"] == online.summary()["events"]
    np.testing.assert_allclose(
        offline.ps.snapshot().table[:, :3], online.ps.snapshot().table[:, :3],
        rtol=1e-9,
    )


def test_cross_run_comparison_finds_regression(tmp_path):
    store_a, reg_a = _make_run(tmp_path, "runA", slow_factor=1.0)
    store_b, reg_b = _make_run(tmp_path, "runB", slow_factor=1.6)
    mon_a = replay(store_a, registry=reg_a, num_funcs=len(reg_a), min_samples=30)
    mon_b = replay(store_b, registry=reg_b, num_funcs=len(reg_b), min_samples=30)
    prof_a = RunProfile.from_monitor("A", mon_a)
    prof_b = RunProfile.from_monitor("B", mon_b)
    rows = compare_runs(prof_a, prof_b)
    assert rows, "comparison must produce rows"
    top = rows[0]
    # the injected 1.6x regression (and its wrapper) must rank first
    assert top["func"] in ("SP_GTXPBL", "SP_GETXBL"), rows[:3]
    assert top["rel_change"] > 0.3
    txt = report(rows)
    assert "SP_GTXPBL" in txt or "SP_GETXBL" in txt
