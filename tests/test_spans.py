"""repro.telemetry.spans: distributed request tracing.

Layers, mirroring the subsystem:

  * ids — 63-bit logical span ids are pure functions of their parts;
    the context derivations (root / wire / server / child) compose into
    a single tree with the STABLE/SAMPLED flag discipline.
  * wire — the "tc" envelope key is a version-tolerant extension of the
    RPN1 frame: tc=None encodes byte-identically to the pre-extension
    framing, a carried context round-trips exactly, malformed contexts
    are loud FramingErrors.
  * ring — the bounded flight recorder: wrap, idempotent dumps, the
    bounded archive, absorb/collect dedup by (trace, span).
  * propagation — a client RPC under an ambient root context produces a
    causally-chained client → server → handler-child span path across
    the transport, including tail-sampling upgrades.
  * replay — a flaky wire forces resends; the stable ids make every
    replayed write collapse to ONE span per hop (no forked trees).
  * export — render_spans is a pure function of the logical span set:
    shuffled input renders byte-identically, and the output passes the
    exporter's own structural validator with client→server flow pairs.
  * end-to-end — a monitored socket-transport run (and, at S ∈ {1,2,4},
    a SIGKILL-and-recover chaos run) exports a validating trace where
    every sampled client RPC has a matched server span and flow arrow,
    byte-identical to a no-fault run of the same seed.
"""
import json
import os
import socket
import time

import numpy as np
import pytest

from repro.core.sim import WorkloadGenerator, nwchem_like
from repro.core.stats import StatsTable
from repro.export.chrome_trace import (
    ChromeTraceWriter,
    SPAN_PID_BASE,
    render_spans,
    validate_trace,
)
from repro.fault.chaos import ChaosStream, FlakyProxy, kill_process
from repro.fault.policy import RetryPolicy
from repro.launch.shard_server import LocalShardHost, ShardServerPool
from repro.net.framing import (
    FrameDecoder,
    FramingError,
    encode_frame,
    pack_payload,
    unpack_payload,
)
from repro.net.shards import RemotePSShard, RemoteProvenanceShard
from repro.telemetry import spans
from repro.telemetry.ring import SpanRing, get_ring
from repro.trace.monitor import ChimbukoMonitor


@pytest.fixture(autouse=True)
def _span_isolation():
    """Every test starts from a clean recorder and leaves tracing off."""
    get_ring().clear()
    prev = os.environ.get("REPRO_SPANS")
    yield
    spans.set_enabled(False)
    if prev is None:
        os.environ.pop("REPRO_SPANS", None)
    else:
        os.environ["REPRO_SPANS"] = prev
    get_ring().clear()


def _wait(pred, timeout=30.0, what="condition"):
    deadline = time.monotonic() + timeout
    while not pred():
        assert time.monotonic() < deadline, f"timeout waiting for {what}"
        time.sleep(0.02)


def _rand_push(rng, F):
    n = int(rng.integers(1, 50))
    delta = StatsTable(F).update_batch(
        rng.integers(0, F, n), rng.lognormal(3.0, 1.0, n)
    )
    idx = np.flatnonzero(delta[:, 0] > 0).astype(np.int64)
    return idx, np.ascontiguousarray(delta[idx])


# A doc shaped the way ProvenanceShard.add requires (rank/step/anomaly
# with fid/entry/exit) — the minimum the ingest path indexes on.
def _prov_doc(rank=0, step=0, fid=1, sev=7):
    return {
        "rank": rank, "step": step, "severity": sev,
        "anomaly": {"fid": fid, "func": "f", "entry": 10, "exit": 20},
    }


# ====================================================================== ids
def test_span_id_deterministic_63bit():
    a = spans.span_id("trace", 0, 7)
    assert a == spans.span_id("trace", 0, 7)  # pure function of parts
    assert 1 <= a < (1 << 63)
    assert spans.span_id("trace", 0, 7) != spans.span_id("trace", 7, 0)
    assert spans.hexid(a) == format(a, "016x")
    # the documented tree derivations chain without collisions
    trace = spans.span_id("trace", 1, 2)
    root = spans.span_id(trace, "frame")
    client = spans.span_id(trace, "ps.push_rows", 5)
    server = spans.span_id(trace, client, "server")
    child = spans.span_id(server, "ps.apply")
    assert len({trace, root, client, server, child}) == 5


def test_context_derivations_and_flags():
    spans.set_enabled(True)
    root = spans.root_context(rank=3, step=16, sample_every=8)
    assert root.flags == spans.STABLE | spans.SAMPLED  # 16 % 8 == 0
    assert spans.root_context(3, 17, 8).flags == spans.STABLE
    with spans.use(spans.root_context(3, 17, 8)):
        # tail sampling: the upgrade rewrites the ambient context in place
        assert not spans.current().sampled
        upgraded = spans.mark_sampled()
        assert upgraded.sampled and spans.current().sampled
        ws = spans.wire_context("ps.push_rows", 5)
        assert ws.flags & spans.STABLE and ws.flags & spans.SAMPLED
        assert ws.parent_id == spans.current().span_id
        assert ws.span_id == spans.span_id(ws.trace_id, "ps.push_rows", 5)
        # the default per-call derivation drops STABLE (rids drift on retry)
        dc = spans.derive_call_context("h:1", 0, 42)
        assert not dc.flags & spans.STABLE and dc.flags & spans.SAMPLED
    srv = spans.server_context(ws.tc())
    assert srv.trace_id == ws.trace_id and srv.flags == ws.flags
    assert srv.span_id == spans.span_id(ws.trace_id, ws.span_id, "server")
    # outside any ambient context there is nothing to derive
    assert spans.current() is None and spans.wire_context("m", 0) is None


def test_child_span_records_and_err_flag():
    spans.set_enabled(True)
    root = spans.root_context(0, 0, 1)
    with spans.use(root):
        with spans.span("ps.apply") as child:
            assert child.span_id == spans.span_id(root.span_id, "ps.apply")
            assert spans.current() is child
        with pytest.raises(RuntimeError):
            with spans.span("boom"):
                raise RuntimeError("x")
    got = {s["name"]: s for s in get_ring().snapshot()}
    assert got["ps.apply"]["parent"] == root.span_id
    assert "err" not in got["ps.apply"]
    assert got["boom"]["err"] == 1


# ===================================================================== wire
def test_tc_envelope_roundtrip_and_pre_extension_bytes():
    env = {"m": 1}
    arrays = (np.arange(6, dtype=np.float64).reshape(2, 3),)
    # version tolerance, direction 1: no context encodes byte-identically
    # to the pre-extension framing (no "tc" key ever hits the envelope)
    plain = pack_payload(env, arrays)
    assert plain == pack_payload(env, arrays, tc=None)
    assert b'"tc"' not in plain
    # direction 2: a carried context round-trips exactly and leaves the
    # env/arrays untouched for handlers that ignore it
    tc = (spans.span_id("trace", 0, 1), spans.span_id("s"), 3)
    got_env, got_arrays, got_tc = unpack_payload(pack_payload(env, arrays, tc))
    assert got_tc == tc and got_env == env
    assert got_arrays[0].tobytes() == arrays[0].tobytes()
    assert unpack_payload(plain)[2] is None
    # the full frame path: FrameDecoder surfaces the context on Frame.tc
    dec = FrameDecoder()
    frames = dec.feed(encode_frame(7, 0, 9, env, arrays, tc=tc))
    assert len(frames) == 1 and frames[0].tc == tc
    assert dec.feed(encode_frame(7, 0, 10, env, arrays))[0].tc is None
    # malformed on-the-wire contexts are loud framing errors, not Nones
    import struct

    bad = json.dumps({"env": {}, "arrays": [], "tc": "nope"}).encode()
    with pytest.raises(FramingError, match="trace context"):
        unpack_payload(struct.pack("!I", len(bad)) + bad)


# ===================================================================== ring
def test_ring_wrap_dump_absorb_collect():
    r = SpanRing(capacity=8)
    mk = lambda i: {"trace": 1, "span": i, "name": f"s{i}", "flags": 3}
    for i in range(20):
        r.record(mk(i))
    assert len(r) == 8  # wrapped: only the most recent 8 live
    assert r.stats()["recorded"] == 20
    assert [s["span"] for s in r.snapshot()] == list(range(12, 20))
    # dumps freeze the ring into the archive, idempotently per span id
    assert r.dump("t1") == 8
    assert r.dump("t2") == 0
    assert [t["reason"] for t in r.triggers()] == ["t1", "t2"]
    # absorb merges a remote view with the same dedup key
    assert r.absorb([mk(12), mk(99)]) == 1
    # collect = archive + live ring, unique by (trace, span)
    keys = [(s["trace"], s["span"]) for s in r.collect()]
    assert len(keys) == len(set(keys)) == 9
    # the archive is bounded at ARCHIVE_FACTOR * capacity, oldest evicted
    r.absorb([{"trace": 2, "span": i, "flags": 3} for i in range(100)])
    assert r.stats()["archived"] == 32
    assert r.stats()["archive_dropped"] > 0
    r.clear()
    assert len(r) == 0 and r.collect() == [] and r.stats()["recorded"] == 0


# ============================================================== propagation
def test_rpc_propagation_client_server_handler(tmp_path):
    """One write RPC under an ambient root: the client span, the server
    span, and the handler child chain into a single stable tree across
    the socket transport (in-process shard host: shared ring)."""
    spans.set_enabled(True)
    with LocalShardHost(1, kind="both") as host:
        ps = RemotePSShard(host.endpoints[0], 0, 1, 16)
        prov = RemoteProvenanceShard(
            host.endpoints[0], path=str(tmp_path / "p.jsonl")
        )
        root = spans.root_context(0, 0, 1)
        rng = np.random.default_rng(0)
        idx, rows = _rand_push(rng, 16)
        with spans.use(root):
            ps.push_sparse_nowait(idx, rows, 16)
            prov.add_many_nowait([_prov_doc()], [0])
            ps.drain()
            prov.drain()
        ps.close()
        prov.close()
    by_name = {}
    for s in get_ring().collect():
        by_name.setdefault(s["name"], []).append(s)
    for method in ("ps.push_rows", "prov.add_many"):
        (client,) = by_name[f"rpc.client:{method}"]
        (server,) = by_name[f"rpc.server:{method}"]
        assert client["flags"] == server["flags"] == spans.STABLE | spans.SAMPLED
        assert client["trace"] == server["trace"] == root.trace_id
        assert client["parent"] == root.span_id
        assert server["parent"] == client["span"]
        assert server["span"] == spans.span_id(
            root.trace_id, client["span"], "server"
        )
        assert client["kind"] == "client"
        assert server["kind"] in ("server", "worker")
    (apply_,) = by_name["ps.apply"]
    (ingest,) = by_name["prov.ingest"]
    assert apply_["parent"] == by_name["rpc.server:ps.push_rows"][0]["span"]
    assert ingest["parent"] == by_name["rpc.server:prov.add_many"][0]["span"]


def test_spans_dump_verb_freezes_remote_recorder(tmp_path):
    """The reserved spans.dump RPC returns the worker's collected spans
    and, with dump=1, archives them with the trigger logged."""
    from repro.net import RPCClient

    spans.set_enabled(True)
    with LocalShardHost(1, kind="ps") as host:
        ps = RemotePSShard(host.endpoints[0], 0, 1, 16)
        with spans.use(spans.root_context(0, 0, 1)):
            idx, rows = _rand_push(np.random.default_rng(1), 16)
            ps.push_sparse_nowait(idx, rows, 16)
            ps.drain()
        cli = RPCClient(host.endpoints[0])
        env, _ = cli.call("spans.dump", {"dump": True, "reason": "test"})
        cli.close()
        ps.close()
    names = {s["name"] for s in env["spans"]}
    assert "rpc.server:ps.push_rows" in names and "ps.apply" in names
    assert env["stats"]["archived"] > 0
    assert any(t["reason"] == "test" for t in env["triggers"])


def test_flaky_replay_collapses_to_single_tree(tmp_path):
    """Resent writes (dropped + torn connections) re-record the *same*
    deterministic ids: the raw ring shows the duplicate recordings, and
    the collected view still holds exactly one client span and one server
    span per logical push — the tree never forks."""
    F, N = 16, 40
    spans.set_enabled(True)
    cs = ChaosStream(77)
    with LocalShardHost(1, kind="ps") as host:
        with FlakyProxy(host.endpoints[0], drop_at=(4 + cs.below(8),),
                        truncate_at=(20 + cs.below(8),)) as proxy:
            stub = RemotePSShard(
                proxy.endpoint, 0, 1, F, wal_dir=str(tmp_path),
                policy=RetryPolicy(retries=8, base_delay=0.02),
            )
            rng = np.random.default_rng(1)
            with spans.use(spans.root_context(0, 0, 1)):
                for _ in range(N):
                    idx, rows = _rand_push(rng, F)
                    stub.push_sparse_nowait(idx, rows, F)
                stub.drain()
            assert proxy.faults == 2
            stub.close()
    raw = [s for s in get_ring().snapshot()
           if s["name"] == "rpc.client:ps.push_rows"]
    assert len(raw) > N  # the replays really did re-record
    col = get_ring().collect()
    clients = {s["span"]: s for s in col
               if s["name"] == "rpc.client:ps.push_rows"}
    servers = {s["parent"]: s for s in col
               if s["name"] == "rpc.server:ps.push_rows"}
    assert len(clients) == N
    # exactly one server span per client span, each a proper child
    assert set(servers) == set(clients)
    for cid, srv in servers.items():
        assert srv["span"] == spans.span_id(srv["trace"], cid, "server")


# =================================================================== export
def _synthetic_fleet():
    """Two traces over two procs, stable+sampled, with a known flow pair
    and some flight-recorder-only (non-exportable) noise mixed in."""
    out = {"monitor": [], "shard0": []}
    for step in (0, 1):
        trace = spans.span_id("trace", 0, step)
        root = spans.span_id(trace, "frame")
        client = spans.span_id(trace, "ps.push_rows", step)
        server = spans.span_id(trace, client, "server")
        child = spans.span_id(server, "ps.apply")
        out["monitor"] += [
            {"trace": trace, "span": root, "parent": 0, "name": "frame",
             "kind": "frame", "flags": 3, "t0": 5, "dur": 9,
             "ord": [step, 0]},
            {"trace": trace, "span": client, "parent": root,
             "name": "rpc.client:ps.push_rows", "kind": "client",
             "flags": 3, "t0": 6, "dur": 7},
            # unstable (rid-derived) spans stay flight-recorder-only
            {"trace": trace, "span": spans.span_id(trace, "call", step),
             "parent": root, "name": "rpc.client:ps.stats",
             "kind": "client", "flags": 1, "t0": 6, "dur": 1},
        ]
        out["shard0"] += [
            {"trace": trace, "span": server, "parent": client,
             "name": "rpc.server:ps.push_rows", "kind": "worker",
             "flags": 3, "t0": 0, "dur": 3},
            {"trace": trace, "span": child, "parent": server,
             "name": "ps.apply", "kind": "span", "flags": 3,
             "t0": 1, "dur": 1},
        ]
    return out


def _render_bytes(path, fleet):
    w = ChromeTraceWriter(path=path)
    n = render_spans(w, fleet)
    w.close()
    with open(path, "rb") as f:
        return n, f.read()


def test_render_spans_pure_function_of_span_set(tmp_path):
    fleet = _synthetic_fleet()
    n, a = _render_bytes(str(tmp_path / "a.json"), fleet)
    assert n == 8  # 2 traces x (frame, client, server, apply); noise cut
    # input order (and duplicate copies, as crash replay federates) is
    # irrelevant: the rendering depends only on the logical span set
    shuffled = {p: list(reversed(v)) + v[:1] for p, v in fleet.items()}
    _, b = _render_bytes(str(tmp_path / "b.json"), shuffled)
    assert a == b
    counts = validate_trace(str(tmp_path / "a.json"))
    assert counts["flows"] == 2 and counts["completes"] == 8
    doc = json.loads(a)
    xs = [e for e in doc["traceEvents"] if e.get("cat") == "span"]
    assert {e["args"]["kind"] for e in xs} == {
        "frame", "client", "worker", "span"
    }
    assert "rpc.client:ps.stats" not in {e["name"] for e in xs}
    # cross-process: monitor and shard0 land on distinct span pids, and
    # the flow arrows tie the client entry tick to the server entry tick
    pids = {e["pid"] for e in xs}
    assert pids == {SPAN_PID_BASE, SPAN_PID_BASE + 1}
    flows = [e for e in doc["traceEvents"] if e.get("cat") == "rpc"]
    clients = {e["args"]["span"]: e for e in xs
               if e["args"]["kind"] == "client"}
    for f in flows:
        assert spans.hexid(f["id"]) in clients


# =============================================================== end-to-end
def _assert_client_server_flows(trace_path):
    """The acceptance predicate: every exported client RPC span has a
    matched server/worker child span and a paired s/f flow arrow."""
    doc = validate_trace(trace_path)  # structural validity first
    raw = json.load(open(trace_path))
    xs = [e for e in raw["traceEvents"]
          if e.get("cat") == "span" and e.get("ph") == "X"]
    clients = [e for e in xs if e["args"]["kind"] == "client"]
    assert clients, "no client RPC spans were exported"
    kids = {}
    for e in xs:
        kids.setdefault(e["args"]["parent"], []).append(e)
    flow_s = {e["id"] for e in raw["traceEvents"]
              if e.get("cat") == "rpc" and e["ph"] == "s"}
    flow_f = {e["id"] for e in raw["traceEvents"]
              if e.get("cat") == "rpc" and e["ph"] == "f"}
    for c in clients:
        served = [k for k in kids.get(c["args"]["span"], ())
                  if k["args"]["kind"] in ("server", "worker")]
        assert served, f"client span {c['args']['span']} has no server span"
        assert int(c["args"]["span"], 16) in flow_s & flow_f
    return doc, xs


def test_monitored_run_exports_flows(tmp_path):
    """A traced socket-transport monitored run: the export carries the
    frame-rooted span trees and validating client->server flow pairs."""
    trace = str(tmp_path / "trace.json")
    spans.set_enabled(True)
    with LocalShardHost(2, kind="both") as host:
        mon = ChimbukoMonitor(
            num_funcs=64, prov_path=str(tmp_path / "p.jsonl"),
            min_samples=8, alpha=6.0, provdb_shards=2,
            ps_transport="socket", provdb_transport="socket",
            shard_endpoints=host.endpoints,
            run_info={"timestamp": 0.0}, export_trace=trace,
            trace_spans=True, span_sample_every=2,
        )
        gen = WorkloadGenerator(nwchem_like(), n_ranks=2, seed=0)
        for step in range(6):
            for rank in range(2):
                mon.ingest(gen.frame(rank, step)[0])
        assert mon.quiesce()["errors"] == []
        fleet = mon.fleet_spans()
        mon.close()
    assert "monitor" in fleet and any(p.startswith("shard") for p in fleet)
    _, xs = _assert_client_server_flows(trace)
    frames = [e for e in xs if e["args"]["kind"] == "frame"]
    # sample_every=2 provisionally keeps half the frames; anomalies may
    # tail-upgrade more but never fewer
    assert len(frames) >= 6
    assert all(e["args"]["parent"] == spans.hexid(0) for e in frames)


def test_gateway_spans_endpoint(tmp_path):
    """/spans federates every process's flight recorder over HTTP and
    ?dump=1 freezes them with the trigger logged."""
    from test_viz_gateway import _get

    spans.set_enabled(True)
    with LocalShardHost(1, kind="both") as host:
        mon = ChimbukoMonitor(
            num_funcs=64, prov_path=str(tmp_path / "p.jsonl"),
            min_samples=8, alpha=6.0,
            ps_transport="socket", provdb_transport="socket",
            shard_endpoints=host.endpoints,
            run_info={"timestamp": 0.0},
            trace_spans=True, span_sample_every=1, viz_serve=0,
        )
        gen = WorkloadGenerator(nwchem_like(), n_ranks=1, seed=0)
        for step in range(3):
            mon.ingest(gen.frame(0, step)[0])
        mon.quiesce()
        status, _, body = _get(mon.viz_gateway.endpoint, "/spans?dump=1")
        mon.close()
    assert status == 200
    doc = json.loads(body)
    assert doc["enabled"] is True and doc["errors"] == []
    assert set(doc["procs"]) == {"gateway", "shard0"}
    shard = doc["procs"]["shard0"]
    assert any(s["name"] == "rpc.server:ps.push_rows" for s in shard["spans"])
    assert any(t["reason"] == "http:/spans" for t in shard["triggers"])


def _traced_run(tmp, S, kill=None):
    """One traced, monitored, socket-transport run; ``kill`` is an
    optional (step, worker_index) SIGKILL injected right after that
    step's quiesce (both variants quiesce there, so the no-kill twin is
    byte-comparable)."""
    os.makedirs(tmp, exist_ok=True)
    get_ring().clear()
    prov = os.path.join(tmp, "prov.jsonl")
    trace = os.path.join(tmp, "trace.json")
    # spawned shard workers read REPRO_SPANS at import: arm before spawn
    os.environ["REPRO_SPANS"] = "1"
    kill_step = kill[0] if kill else 5
    with ShardServerPool(S, kind="both", supervise=True,
                         supervise_poll=0.05) as pool:
        mon = ChimbukoMonitor(
            num_funcs=64, prov_path=prov, min_samples=8, alpha=6.0,
            provdb_shards=S,
            ps_transport="socket", provdb_transport="socket",
            shard_endpoints=pool.endpoints,
            ps_wal_dir=os.path.join(tmp, "wal"),
            fault_policy=RetryPolicy(retries=8, base_delay=0.05),
            run_info={"timestamp": 0.0}, export_trace=trace,
            trace_spans=True, span_sample_every=4,
        )
        spec = nwchem_like(anomaly_rate=0.02)
        for f in spec.funcs.values():
            f.anomaly_scale = 40.0
        gen = WorkloadGenerator(spec, n_ranks=2, seed=0)
        for step in range(12):
            for rank in range(2):
                mon.ingest(gen.frame(rank, step)[0])
            if step == kill_step:
                # quiesce first: all acked writes' server spans are now
                # archived monitor-side, so the SIGKILL cannot orphan a
                # sampled trace (the byte-identity anchor)
                mon.quiesce()
                if kill:
                    victim = pool.procs[kill[1]]
                    kill_process(victim)
                    victim.join(10)
                    _wait(lambda: pool.restarts >= 1,
                          what="supervisor respawn")
        mon.quiesce()
        mon.close()
        fleet = mon.fleet_spans()
        restarts = pool.restarts
    with open(trace, "rb") as f:
        return f.read(), fleet, restarts


def _assert_single_trees(fleet):
    """S3: across the whole federated fleet view, the stable span set
    forms exactly one tree per trace — crash replay deduplicated."""
    merged = {}
    for proc, view in fleet.items():
        for s in view:
            if s["flags"] & spans.STABLE:
                prior = merged.get((s["trace"], s["span"]))
                if prior is not None:
                    # a replayed span may surface from several recorders,
                    # but always with identical logical content
                    for k in ("parent", "name", "kind", "flags"):
                        assert prior[k] == s[k]
                merged[(s["trace"], s["span"])] = s
    by_trace = {}
    for (trace, _sid), s in merged.items():
        by_trace.setdefault(trace, {})[s["span"]] = s
    assert by_trace
    for trace, members in by_trace.items():
        roots = [s for s in members.values()
                 if s["kind"] == "frame" and not s["parent"]]
        assert len(roots) == 1, f"trace {trace:x} has {len(roots)} roots"
        for s in members.values():  # every parent chain reaches the root
            seen, cur = set(), s
            while cur["parent"]:
                assert cur["span"] not in seen, "cycle in span tree"
                seen.add(cur["span"])
                cur = members[cur["parent"]]
            assert cur["span"] == roots[0]["span"]


@pytest.mark.parametrize("S", [1, 2, 4])
def test_traced_chaos_kill_byte_identical_export(tmp_path, S):
    """Acceptance: SIGKILL a live PS/prov worker mid-run at S shards;
    the replayed writes re-derive identical span ids, so the exported
    trace byte-matches the no-fault twin of the same seed, validates,
    and pairs every sampled client RPC with its server span by a flow
    arrow; the fleet view holds one tree per trace."""
    from repro.core.provenance import static_provenance

    static_provenance()  # settle lazy env probes before the first run
    cs = ChaosStream(4040 + S)
    kill = (4 + cs.below(4), cs.below(S))
    ref_trace, _f, ref_restarts = _traced_run(str(tmp_path / "ref"), S)
    trace, fleet, restarts = _traced_run(str(tmp_path / "kill"), S, kill)

    assert ref_restarts == 0 and restarts >= 1
    assert trace == ref_trace, "kill-run export diverged from no-fault twin"
    _assert_client_server_flows(str(tmp_path / "kill" / "trace.json"))
    _assert_single_trees(fleet)
