"""Property tests: Pébay merges are partition-invariant over PS shards."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import stats as S
from repro.core.ps import FederatedPS, ParameterServer
from repro.core.stats import StatsTable

values = st.lists(
    st.floats(min_value=1e-3, max_value=1e6, allow_nan=False, width=64),
    min_size=0,
    max_size=120,
)


@given(
    data=st.lists(st.tuples(st.integers(0, 30), values), min_size=1, max_size=8),
    num_shards=st.integers(1, 6),
)
@settings(max_examples=40, deadline=None)
def test_merge_partition_invariant(data, num_shards):
    """Sharding the fid space arbitrarily never changes the merged moments."""
    F = 31
    single = StatsTable(F)
    fed = FederatedPS(F, num_shards=num_shards)
    for i, (fid, xs) in enumerate(data):
        delta = StatsTable(F).update_batch(
            np.full(len(xs), fid, np.int64), np.asarray(xs, np.float64)
        )
        single.merge_array(delta)
        fed.update_and_fetch(0, i, delta)
    assert np.array_equal(single.table, fed.snapshot().table)


@given(
    xs=st.lists(
        st.floats(min_value=1e-3, max_value=1e5, allow_nan=False, width=64),
        min_size=1,
        max_size=200,
    ),
    cuts=st.lists(st.integers(0, 199), max_size=4),
)
@settings(max_examples=40, deadline=None)
def test_row_merge_split_invariant(xs, cuts):
    """merge_moments over any split of a value stream ~= one-shot moments."""
    x = np.asarray(xs, np.float64)
    bounds = sorted({min(c, len(xs)) for c in cuts} | {0, len(xs)})
    row = S.empty_table(1)[0]
    for lo, hi in zip(bounds, bounds[1:]):
        row = S.merge_moments(row, S.batch_moments(x[lo:hi]))
    ref = S.batch_moments(x)
    assert np.isclose(row[S.N], ref[S.N])
    if ref[S.N] > 0:
        scale = max(abs(ref[S.MEAN]), 1.0)
        assert np.isclose(row[S.MEAN], ref[S.MEAN], rtol=1e-9, atol=1e-6 * scale)
        assert np.isclose(row[S.M2], ref[S.M2], rtol=1e-6, atol=1e-3 * scale**2)
        assert row[S.MIN] == ref[S.MIN] and row[S.MAX] == ref[S.MAX]


@given(num_shards=st.integers(1, 8), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_random_stream_bitmatch(num_shards, seed):
    """Random event streams: federated == single-instance, bit for bit."""
    rng = np.random.default_rng(seed)
    F = int(rng.integers(4, 50))
    single = ParameterServer(F)
    fed = FederatedPS(F, num_shards=num_shards)
    for t in range(int(rng.integers(1, 12))):
        n = int(rng.integers(0, 60))
        delta = StatsTable(F).update_batch(
            rng.integers(0, F, n), rng.lognormal(3, 1, n)
        )
        single.update_and_fetch(0, t, delta)
        fed.update_and_fetch(0, t, delta)
    assert np.array_equal(single.snapshot().table, fed.snapshot().table)
