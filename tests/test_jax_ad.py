"""On-device AD: jnp tables vs host oracle; distributed psum merge."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import jax_ad as J
from repro.core.stats import StatsTable


def test_batch_table_matches_host():
    rng = np.random.default_rng(0)
    fids = rng.integers(0, 16, 300).astype(np.int32)
    durs = rng.lognormal(3, 1, 300).astype(np.float32)
    # add padding
    fids = np.concatenate([fids, -np.ones(50, np.int32)])
    durs = np.concatenate([durs, np.zeros(50, np.float32)])
    t = J.batch_table(jnp.asarray(fids), jnp.asarray(durs), 16)
    host = StatsTable(16)
    host.update_batch(fids[:300].astype(np.int64), durs[:300].astype(np.float64))
    np.testing.assert_allclose(np.asarray(t[:, J.N]), host.counts(), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(t[:, J.MEAN]), host.means(), rtol=1e-4, atol=1e-3)
    m2_host = host.table[:, 2]
    np.testing.assert_allclose(np.asarray(t[:, J.M2]), m2_host, rtol=1e-3, atol=1.0)


def test_merge_tables_matches_host():
    rng = np.random.default_rng(1)
    a_f, a_d = rng.integers(0, 8, 100), rng.lognormal(2, 0.5, 100)
    b_f, b_d = rng.integers(0, 8, 150), rng.lognormal(2, 0.5, 150)
    ta = J.batch_table(jnp.asarray(a_f, jnp.int32), jnp.asarray(a_d, jnp.float32), 8)
    tb = J.batch_table(jnp.asarray(b_f, jnp.int32), jnp.asarray(b_d, jnp.float32), 8)
    merged = J.merge_tables(ta, tb)
    host = StatsTable(8)
    host.update_batch(np.concatenate([a_f, b_f]), np.concatenate([a_d, b_d]))
    np.testing.assert_allclose(np.asarray(merged[:, J.N]), host.counts(), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(merged[:, J.MEAN]), host.means(), rtol=1e-4)


def test_ad_step_labels():
    table = J.init_table(4)
    rng = np.random.default_rng(3)
    fids = jnp.asarray(rng.integers(0, 4, 400), jnp.int32)
    durs = jnp.asarray(rng.normal(100, 5, 400), jnp.float32)
    table, labels = J.ad_step(table, fids, durs)
    assert int(labels.sum()) == 0
    # now inject one extreme event
    f2 = jnp.asarray([0, 1], jnp.int32)
    d2 = jnp.asarray([100.0, 5000.0], jnp.float32)
    table, labels = J.ad_step(table, f2, d2)
    assert labels.tolist() == [0, 1]


def test_straggler_scores():
    times = jnp.asarray([1.0, 1.05, 0.98, 1.02, 4.0])
    z = J.straggler_scores(times)
    assert int(jnp.argmax(z)) == 4 and float(z[4]) > 1.5


_DISTRIBUTED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import jax_ad as J
from repro.core.stats import StatsTable
mesh = jax.make_mesh((8,), ("ranks",))
step = J.make_distributed_ad_step(mesh, ("ranks",), min_count=10.0)
rng = np.random.default_rng(0)
F, R, E = 32, 8, 256
fids = rng.integers(0, F, (R, E)).astype(np.int32)
durs = rng.lognormal(3, 0.4, (R, E)).astype(np.float32)
table = J.init_table(F)
new_table, labels = step(table, jnp.asarray(fids), jnp.asarray(durs))
host = StatsTable(F)
host.update_batch(fids.reshape(-1).astype(np.int64), durs.reshape(-1).astype(np.float64))
np.testing.assert_allclose(np.asarray(new_table[:, 0]), host.counts(), rtol=1e-6)
np.testing.assert_allclose(np.asarray(new_table[:, 1]), host.means(), rtol=1e-4)
np.testing.assert_allclose(
    np.sqrt(np.maximum(np.asarray(new_table[:, 2]) / np.maximum(np.asarray(new_table[:, 0]), 1), 0)),
    host.stds(), rtol=1e-3, atol=1e-2)
# labeling: second step flags an injected outlier on one shard only
fids2 = np.zeros((R, 4), np.int32); durs2 = np.full((R, 4), float(host.means()[0]), np.float32)
durs2[3, 2] = 1e6
_, labels2 = step(new_table, jnp.asarray(fids2), jnp.asarray(durs2))
lab = np.asarray(labels2)
assert lab[3, 2] == 1 and lab.sum() == 1, lab
print("DISTRIBUTED_AD_OK")
"""


def test_distributed_ad_multidevice():
    """PS-as-psum on 8 fake devices == exact host stats (Fig. 7 equivalence)."""
    r = subprocess.run(
        [sys.executable, "-c", _DISTRIBUTED_SCRIPT],
        capture_output=True,
        text=True,
        timeout=300,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "DISTRIBUTED_AD_OK" in r.stdout, r.stdout + r.stderr
