"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.mamba_scan import mamba_scan as ms_raw
from repro.kernels.moments import moments_and_labels as mo_raw


# ---------------------------------------------------------------- moments
@pytest.mark.parametrize("N,F,EB", [(64, 16, 32), (500, 128, 128), (1000, 7, 512)])
def test_moments_kernel_sweep(N, F, EB):
    rng = np.random.default_rng(N + F)
    fids = rng.integers(-1, F, N).astype(np.int32)  # includes padding (-1)
    durs = rng.lognormal(3, 1, N).astype(np.float32)
    # previous table with some mass so labeling paths fire
    prev_f = rng.integers(0, F, 4 * F).astype(np.int32)
    prev_x = rng.lognormal(3, 0.2, 4 * F).astype(np.float32)
    prev, _ = ref.moments_and_labels_ref(jnp.asarray(prev_f), jnp.asarray(prev_x),
                                         jnp.zeros((F, 5)))
    # put a few extreme outliers in
    durs[:3] = 1e5

    d_k, l_k = mo_raw(jnp.asarray(fids), jnp.asarray(durs), prev,
                      block_events=EB, interpret=True)
    d_r, l_r = ref.moments_and_labels_ref(jnp.asarray(fids), jnp.asarray(durs), prev)
    np.testing.assert_allclose(np.asarray(d_k[:, :3]), np.asarray(d_r[:, :3]),
                               rtol=1e-5, atol=1e-2)
    seen = np.asarray(d_r[:, 0]) > 0
    np.testing.assert_allclose(np.asarray(d_k[seen, 3:]), np.asarray(d_r[seen, 3:]),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(l_k), np.asarray(l_r))


def test_moments_ops_matches_jax_ad():
    """Kernel-backed ad_step == reference jax_ad.ad_step."""
    from repro.core import jax_ad as J

    rng = np.random.default_rng(0)
    F = 32
    fids = jnp.asarray(rng.integers(0, F, 600), jnp.int32)
    durs = jnp.asarray(rng.normal(100, 5, 600), jnp.float32)
    t_ref, lab_ref = J.ad_step(J.init_table(F), fids, durs)
    t_k, lab_k = ops.moments_update(J.init_table(F), fids, durs)
    np.testing.assert_allclose(np.asarray(t_k[:, :2]), np.asarray(t_ref[:, :2]),
                               rtol=1e-5, atol=1e-3)
    # M2 via raw sums cancels catastrophically in f32 (documented in
    # kernels/moments.py); sigma needs ~3 digits for a 6-sigma detector.
    np.testing.assert_allclose(np.asarray(t_k[:, 2]), np.asarray(t_ref[:, 2]),
                               rtol=1e-2, atol=1e-1)
    np.testing.assert_array_equal(np.asarray(lab_k), np.asarray(lab_ref))
    # one extreme event flags identically
    f2 = jnp.asarray([0, 1], jnp.int32)
    d2 = jnp.asarray([100.0, 9000.0], jnp.float32)
    _, l2r = J.ad_step(t_ref, f2, d2)
    _, l2k = ops.moments_update(t_k, f2, d2)
    assert l2k.tolist() == l2r.tolist() == [0, 1]


# ---------------------------------------------------------- flash attention
CASES = [
    # (B, Sq, Sk, H, KV, hd, causal, window, cap, dtype)
    (2, 128, 128, 4, 4, 64, True, 0, 0.0, jnp.float32),
    (1, 256, 256, 4, 2, 64, True, 0, 0.0, jnp.float32),
    (2, 128, 128, 8, 1, 64, True, 0, 0.0, jnp.bfloat16),  # MQA
    (1, 256, 256, 4, 4, 64, False, 0, 0.0, jnp.float32),  # encoder
    (1, 256, 256, 4, 2, 64, True, 100, 0.0, jnp.float32),  # SWA
    (1, 128, 128, 2, 2, 64, True, 0, 50.0, jnp.float32),  # softcap
    (1, 128, 128, 2, 2, 120, True, 0, 0.0, jnp.float32),  # danube head_dim
    (1, 128, 128, 2, 1, 256, True, 64, 30.0, jnp.bfloat16),  # gemma-ish combo
]


@pytest.mark.parametrize("B,Sq,Sk,H,KV,hd,causal,window,cap,dtype", CASES)
def test_flash_attention_sweep(B, Sq, Sk, H, KV, hd, causal, window, cap, dtype):
    rng = np.random.default_rng(hd + Sq + H)
    q = jnp.asarray(rng.normal(0, 1, (B, Sq, H, hd)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, Sk, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, Sk, KV, hd)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                              block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_attention_kv_len():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 1, (1, 64, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 128, 2, 64)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, kv_len=77, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=False, kv_len=77)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_layer():
    """Kernel == the model's XLA chunked path (same math, two backends)."""
    from repro.models import layers as L

    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(0, 1, (2, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (2, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 256, 2, 64)), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    b = L.attention_chunked(q, k, v, causal=True, chunk_q=64, chunk_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------- mamba scan
@pytest.mark.parametrize("B,S,di,st,bd,Lc", [
    (1, 64, 16, 4, 8, 16),
    (2, 128, 64, 16, 32, 32),
    (1, 256, 32, 16, 32, 64),
])
def test_mamba_scan_sweep(B, S, di, st, bd, Lc):
    rng = np.random.default_rng(S + di)
    a = np.exp(-rng.uniform(0.05, 2.0, (B, S, di, st))).astype(np.float32)
    b = rng.normal(0, 1, (B, S, di, st)).astype(np.float32)
    C = rng.normal(0, 1, (B, S, st)).astype(np.float32)
    y, h = ms_raw(jnp.asarray(a), jnp.asarray(b), jnp.asarray(C),
                  block_d=bd, chunk=Lc, interpret=True)
    y_r, h_r = ref.mamba_scan_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(C))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_r), rtol=1e-5, atol=1e-5)


def test_mamba_scan_matches_model_chunked():
    from repro.models.mamba import _ssm_scan_chunked

    rng = np.random.default_rng(3)
    B, S, di, st = 2, 128, 32, 8
    a = np.exp(-rng.uniform(0.05, 2.0, (B, S, di, st))).astype(np.float32)
    b = rng.normal(0, 1, (B, S, di, st)).astype(np.float32)
    C = rng.normal(0, 1, (B, S, st)).astype(np.float32)
    y_k, h_k = ops.mamba_scan(jnp.asarray(a), jnp.asarray(b), jnp.asarray(C),
                              block_d=16, chunk=32)
    y_m, h_m = _ssm_scan_chunked(jnp.asarray(a), jnp.asarray(b), jnp.asarray(C), chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m), rtol=1e-4, atol=1e-4)
